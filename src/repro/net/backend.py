"""Parent-side socket execution backend (DESIGN.md §Net).

``SocketBackend`` is the third ``ExecutionBackend``: the same
``run_ingest_worker`` loop the process backend runs in a spawn child, but
across a TCP connection, framed by the shared ``repro.net.wire`` codec.
Everything the runtime contract demands stays parent-side and
transport-invariant: the ``BoundedEdgeQueue`` (ALL backpressure / drop /
spill accounting), ``SnapshotBuffer.adopt_published`` (epoch ordering),
checkpoint orchestration, and conservation reports.

Two placements per worker:

  self-hosted  (default, no addresses) the parent binds a loopback
               listener on an ephemeral port and spawns a child process
               that dials back and serves one worker session — one
               command, real TCP end-to-end;
  remote       (``SocketBackend(addresses=[...])`` or the
               ``"socket:HOST:PORT,..."`` spec) the parent dials
               ``stream_ingest --listen`` worker hosts, round-robin over
               the address list.

Lifecycle is hang-free by construction: accept/dial loops poll a cancel
event (set by ``request_stop`` and ``SocketBackend.shutdown()``, which
``Runtime.stop()`` invokes before joining), every read/write carries a
frame deadline, and a dead TCP peer surfaces as a FAILED worker whose
error carries the last-known accounting — so ``Runtime.stop()`` raises
``WorkerFailure`` with the final report attached, mirroring the process
backend's SIGKILL semantics.
"""
from __future__ import annotations

import multiprocessing
import socket
import threading
import time
from collections import deque

from repro.net import wire
from repro.net.ingest_server import _selfhost_worker_main
from repro.runtime.backend import (
    ExecutionBackend,
    build_child_spec,
    dispatch_parent_message,
)
from repro.runtime.metrics import WorkerMetrics
from repro.runtime.worker import CREATED, DRAINING, FAILED, RUNNING, STOPPED

# Redial replay bound: in-flight items retained past this many (publishes
# too rare to ever cover them) forfeit the reconnect safety net rather
# than grow without bound.
_RETAIN_CAP = 8192


class SocketWorker:
    """Parent-side handle for one ingest worker across a TCP connection.

    Quacks like ``IngestWorker``/``ProcessWorker`` for everything the
    supervisor touches.  Three parent threads cooperate, exactly as in the
    process backend: a *starter* establishes the connection (accept or
    dial) and sends the ``hello`` spec, the *forwarder* moves ``QueueItem``
    frames from the parent's bounded queue onto the socket, and the
    *receiver* adopts published epochs into the parent ``SnapshotBuffer``.
    """

    def __init__(self, tenant, queue, policy, *, address=None,
                 reservoir=None, checkpoint_dir=None, checkpoint_every=0,
                 on_publish=None, poll_s=0.05, coalesce_batches=1,
                 coalesce_target=8192, queue_capacity=64, warm_shapes=True,
                 child_env=None, ctx=None, connect_timeout_s=300.0,
                 frame_deadline_s=120.0, auth_token=None,
                 publish_mode="delta", dedup=False) -> None:
        import jax

        self.tenant = tenant
        self.queue = queue
        self.on_publish = on_publish
        self.reservoir = reservoir  # kept live from shipped publish state
        self.state = CREATED
        self.error: BaseException | None = None
        self.error_tb: str | None = None
        self.base_edges = (tenant.snapshot.n_edges
                          + tenant.buffer.pending_edges)
        self.poll_s = poll_s
        self.frame_deadline_s = frame_deadline_s
        self.connect_timeout_s = connect_timeout_s
        self._treedef = jax.tree_util.tree_structure(tenant.snapshot.sketch)
        # kept for the redial path: a reconnect rebuilds a FRESH spec from
        # the tenant's then-current (adopted) state, not this stale one
        self._policy = policy
        self._spec_kwargs = dict(
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            poll_s=poll_s, coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, queue_capacity=queue_capacity,
            warm_shapes=warm_shapes, env=dict(child_env or {}),
            publish_mode=publish_mode, dedup=dedup)
        self._spec = build_child_spec(tenant, policy, reservoir=reservoir,
                                      **self._spec_kwargs)
        self.auth_token = wire.resolve_auth_token(auth_token)
        self.address = address  # None ⇒ self-hosted loopback child
        self._sock: socket.socket | None = None
        self._send_lock = threading.Lock()  # forwarder vs checkpoint vs stop
        self._listener: socket.socket | None = None
        self.process = None
        if address is None:
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.bind(("127.0.0.1", 0))
            self._listener.listen(1)
            host, port = self._listener.getsockname()[:2]
            ctx = ctx or multiprocessing.get_context("spawn")
            self.process = ctx.Process(
                target=_selfhost_worker_main,
                args=(host, port, dict(child_env or {})),
                daemon=True, name=f"ingest-sock-{tenant.key.tenant_id}")
        self._ingested_offset = tenant.offset - 1
        self._last_metrics: dict | None = None
        self._fallback_metrics = WorkerMetrics()
        self._ready = threading.Event()
        self._connected = threading.Event()
        self._done = threading.Event()
        self._stop_event = threading.Event()
        self._abort_connect = threading.Event()
        self._fail_lock = threading.Lock()
        self._drain = True
        self._hard_stop = False
        self._started = False
        self._ckpt_lock = threading.Lock()
        self._ckpt_event = threading.Event()
        self._ckpt_result: dict | None = None
        # ---- single-retry redial state (standing hosts only) -------------
        # Retained items are in-flight work: forwarded to the worker but
        # not yet covered by an ADOPTED publish — exactly what a fresh
        # session must replay for the edge-conservation gates to hold.
        # Lock split (lock-discipline rule): retain-buffer state belongs to
        # _retain_lock, redial arbitration state to _fail_lock — the old
        # code wrote `_redial_used` under _retain_lock on overflow, racing
        # the _fail_lock-guarded read in _peer_lost.  Overflow now sets
        # `_retain_forfeited` (retain-owned); the redial path reads it
        # under _retain_lock where it decides eligibility/replay.
        self._retain_lock = threading.Lock()
        self._retained: deque = deque()  # guarded-by: _retain_lock
        self._retain_active = address is not None  # guarded-by: _retain_lock
        # replay set overflowed _RETAIN_CAP: conservation can no longer be
        # proven across a reconnect, so a redial must fail loudly instead
        self._retain_forfeited = False  # guarded-by: _retain_lock
        self._covered_edges = self.base_edges  # guarded-by: _retain_lock
        self._redial_used = False  # guarded-by: _fail_lock
        self._redialing = False  # guarded-by: _fail_lock
        self._redial_event = threading.Event()  # cleared while redialing
        self._redial_event.set()
        self._rx_quiesced = threading.Event()  # old-session receiver idle

    # -------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Non-blocking: connection establishment happens in a starter
        thread so ``Runtime.start()`` brings K workers up concurrently."""
        self._started = True
        self.state = RUNNING
        threading.Thread(target=self._connect_and_attach, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-dial").start()

    def _accept_selfhost(self) -> socket.socket:
        self.process.start()
        self._listener.settimeout(0.5)
        deadline = time.monotonic() + self.connect_timeout_s
        while time.monotonic() < deadline:
            if self._abort_connect.is_set():
                raise ConnectionAbortedError(
                    "worker accept cancelled by stop/shutdown")
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                if not self.process.is_alive():
                    raise ConnectionError(
                        "self-hosted socket worker died before dialing back "
                        f"(exitcode={self.process.exitcode})") from None
                continue
            except OSError as exc:
                raise ConnectionAbortedError(
                    f"worker listener closed before the worker connected "
                    f"({exc!r})") from exc
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return conn
        raise TimeoutError(
            f"self-hosted worker did not dial back within "
            f"{self.connect_timeout_s}s")

    def _connect_and_attach(self) -> None:
        try:
            if self.address is None:
                sock = self._accept_selfhost()
            else:
                sock = wire.connect_with_retry(
                    self.address, deadline_s=self.connect_timeout_s,
                    stop=self._abort_connect)
            self.close_listener()  # one peer per worker; stop accepting
            with self._send_lock:
                if self.address is not None and self.auth_token:
                    # remote worker host: present the shared token before
                    # the hello (hosts without one ignore the frame)
                    wire.send_message(sock, ("auth", self.auth_token),
                                      deadline_s=self.frame_deadline_s)
                wire.send_message(sock, ("hello", self._spec),
                                  deadline_s=self.frame_deadline_s)
            self._sock = sock
        except BaseException as exc:
            import traceback

            if self._hard_stop or (self._stop_event.is_set()
                                   and self._abort_connect.is_set()):
                self.state = STOPPED  # stop cancelled the dial; not a crash
            else:
                self.error = exc
                self.error_tb = traceback.format_exc()
                self.state = FAILED
            self.close_transport()
            self._ready.set()
            self._ckpt_event.set()
            self._done.set()
            return
        self._connected.set()
        if self._hard_stop:  # killed while dialing; tear the link down
            self.close_transport()
            self._finalize_dead_peer(None)
            return
        threading.Thread(target=self._receive_loop, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-rcv").start()
        threading.Thread(target=self._forward_loop, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-fwd").start()

    def wait_ready(self, timeout: float = 300.0) -> bool:
        ok = self._ready.wait(timeout)
        if self.state == FAILED:
            raise RuntimeError(
                f"socket worker for {self.tenant.key.tenant_id} failed "
                f"during startup: {self.error}\n{self.error_tb or ''}")
        return ok

    def request_stop(self, drain: bool = True) -> None:
        self._drain = drain
        self._stop_event.set()
        if drain:
            if self.state == RUNNING:
                self.state = DRAINING
        else:
            # crash-like hard stop, PR 5 SIGKILL semantics: abandon
            # in-queue and in-flight work; restore replays from checkpoint
            self._hard_stop = True
            self._abort_connect.set()
            self.queue.close()
            self.close_transport()
            if self.process is not None and self.process.is_alive():
                self.process.terminate()
            if not self._connected.is_set():
                self._done.set()  # starter owns the rest of the teardown

    def join(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout

        def remaining(default=None):
            if deadline is None:
                return default
            return max(deadline - time.monotonic(), 0.01)

        self._done.wait(timeout=remaining())
        if self.process is not None and self.process.is_alive():
            self.process.join(timeout=remaining(60.0))
        self.close_transport()

    def is_alive(self) -> bool:
        return self._started and not self._done.is_set()

    # -------------------------------------------------------- transport utils
    def close_listener(self) -> None:
        """Close the self-host accept listener (idempotent).  Called once a
        peer is attached, by hard stops, and by ``SocketBackend.shutdown()``
        so ``Runtime.stop()`` never joins against a worker stuck in
        accept."""
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass

    def close_transport(self) -> None:
        self.close_listener()
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass

    def abort_connect(self) -> None:
        """Cancel a pending dial/accept (used by backend shutdown)."""
        self._abort_connect.set()

    def _accounting_tail(self) -> str:
        m = self._last_metrics or {}
        return ("last-known accounting: "
                f"ingested_edges={m.get('ingested_edges', 0)}, "
                f"ingested_batches={m.get('ingested_batches', 0)}, "
                f"published_epochs={m.get('published_epochs', 0)}, "
                f"epoch={self.tenant.epoch}, "
                f"ingested_offset={self._ingested_offset}")

    def _finalize_dead_peer(self, exc: BaseException | None) -> None:
        """The TCP peer is gone without a terminal message (or we tore it
        down).  Mirrors ``ProcessWorker._finalize_death``: hard stops read
        as STOPPED, anything else is a FAILED worker whose error carries
        the last-known accounting so ``WorkerFailure.report`` plus this
        message tell the whole story."""
        with self._fail_lock:
            if self._done.is_set():
                return
            if self._hard_stop:
                self.state = STOPPED
            else:
                detail = f" ({exc!r})" if exc is not None else ""
                self.error = ConnectionError(
                    f"socket worker for {self.tenant.key.tenant_id} lost its "
                    f"TCP peer{detail}; {self._accounting_tail()}")
                self.error_tb = None
                self.state = FAILED
            self.close_transport()
            if self.process is not None and self.process.is_alive():
                self.process.terminate()
            self._ready.set()
            self._ckpt_event.set()
            self._done.set()

    def _send(self, msg) -> None:
        with self._send_lock:
            wire.send_message(self._sock, msg,
                              deadline_s=self.frame_deadline_s)

    def _send_on(self, sock, msg) -> None:
        """Send bound to ONE connection: a thread still holding the old
        socket after a redial must fail here instead of interleaving its
        frames with the new session's stream."""
        with self._send_lock:
            if sock is not self._sock:
                raise ConnectionResetError("connection superseded by redial")
            wire.send_message(sock, msg, deadline_s=self.frame_deadline_s)

    def _send_frame_on(self, sock, frame) -> None:
        with self._send_lock:
            if sock is not self._sock:
                raise ConnectionResetError("connection superseded by redial")
            wire.send_frame(sock, frame, deadline_s=self.frame_deadline_s)

    def send_control(self, msg) -> None:
        """Parent→worker control frame outside the forwarder's item stream
        (the adopt path's resync request after a ``StaleDelta``)."""
        self._send(msg)

    def _note_publish_adopted(self, n_edges: int) -> None:
        """Adopt-side redial bookkeeping: retained in-flight items wholly
        covered by the adopted cumulative edge count can never need
        replay — pop them.  Exact because the transport is FIFO and the
        worker coalesces whole items, so adopted counts always land on
        item boundaries (zero-edge items pop early, a counter no-op)."""
        with self._retain_lock:
            while (self._retained and self._covered_edges
                   + self._retained[0].n_edges <= n_edges):
                self._covered_edges += self._retained.popleft().n_edges

    # ----------------------------------------------------------------- redial
    def _peer_lost(self, sock, exc) -> bool:
        """Peer-death policy, called by forward/receive on a dead ``sock``.

        Standing hosts (``address`` set) get ONE bounded reconnect-and-
        resync before the loud ``WorkerFailure``; self-hosted children keep
        the existing fail-fast semantics (their process died — there is
        nothing to re-dial).  Returns True when a redial replaced the
        connection (caller continues against the new session), False when
        the handle was finalized (caller must exit)."""
        with self._fail_lock:
            if self._done.is_set():
                return False
            if sock is not self._sock:
                return True  # a concurrent redial already replaced the link
            with self._retain_lock:  # static edge _fail_lock -> _retain_lock
                forfeited = self._retain_forfeited
            if self._redialing:
                action = "wait"
            elif (self.address is not None and not self._redial_used
                  and not forfeited and not self._hard_stop):
                self._redial_used = True
                self._redialing = True
                self._redial_event.clear()
                action = "redial"
            else:
                action = "fail"
        if action == "fail":
            self._finalize_dead_peer(exc)
            return False
        if action == "wait":
            self._redial_event.wait(self.connect_timeout_s + 60.0)
            with self._fail_lock:
                return not self._done.is_set() and sock is not self._sock
        ok = False
        try:
            ok = self._try_redial()
        finally:
            with self._fail_lock:
                self._redialing = False
            self._redial_event.set()
        if not ok:
            self._finalize_dead_peer(exc)
            return False
        # the old receiver quiesced permanently; give the new session one
        threading.Thread(target=self._receive_loop, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-rcv2").start()
        return True

    def _try_redial(self) -> bool:
        """One reconnect: fresh hello spec from the tenant's adopted state,
        then replay of every retained in-flight item, then socket swap.

        Ordering is what makes this safe: (1) the old session's receiver
        must be quiescent before the replay set is frozen — a publish
        adopted after freezing would double-fold the items it covers;
        (2) replay + swap run under both the retain and send locks, so a
        straggling forwarder send can neither interleave with the resync
        stream nor slip an unreplayed item past it."""
        if not self._rx_quiesced.wait(timeout=30.0):
            return False
        try:
            self._sock.close()  # also kills a half-alive old session
        except OSError:
            pass
        sock = None
        try:
            sock = wire.connect_with_retry(
                self.address, deadline_s=min(30.0, self.connect_timeout_s),
                stop=self._abort_connect)
            spec = build_child_spec(self.tenant, self._policy,
                                    reservoir=self.reservoir,
                                    **self._spec_kwargs)
            with self._send_lock:
                if self.auth_token:
                    wire.send_message(sock, ("auth", self.auth_token),
                                      deadline_s=self.frame_deadline_s)
                wire.send_message(sock, ("hello", spec),
                                  deadline_s=self.frame_deadline_s)
                with self._retain_lock:
                    if self._retain_forfeited:
                        # the forwarder overflowed the replay buffer AFTER
                        # eligibility was checked: the freeze-time state no
                        # longer covers every in-flight edge, so resyncing
                        # would silently lose work.  Fail the redial — the
                        # caller raises a loud WorkerFailure instead.
                        raise ConnectionError(
                            "retained replay set forfeited mid-redial")
                    for it in self._retained:
                        wire.send_frame(sock, wire.encode_item_frame(it),
                                        deadline_s=self.frame_deadline_s)
                    self._retained.clear()
                    self._retain_active = False  # single retry: no 2nd replay
                    self._sock = sock
            return True
        except BaseException:
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
            return False

    # -------------------------------------------------------------- transport
    def _forward_loop(self) -> None:
        while not self._ready.wait(timeout=0.1):
            if self._done.is_set() or self._hard_stop:
                return
        while True:
            if self._done.is_set() or self._hard_stop:
                return
            item = self.queue.get(timeout=self.poll_s)
            if item is None:
                if (self._stop_event.is_set() and self._drain
                        and self.queue.depth() == 0):
                    break
                continue
            # columnar fast path: raw buffer views, no pickle (v3 frames)
            frame = wire.encode_item_frame(item)
            with self._retain_lock:
                if self._retain_active:
                    self._retained.append(item)
                    if len(self._retained) > _RETAIN_CAP:
                        # too much un-adopted in-flight work to ever replay;
                        # forfeit (NOT `_redial_used = True`: that field is
                        # _fail_lock state — writing it here raced the
                        # redial arbitration in _peer_lost)
                        self._retained.clear()
                        self._retain_active = False
                        self._retain_forfeited = True
                sock = self._sock
            try:
                self._send_frame_on(sock, frame)
            except (ConnectionError, TimeoutError, OSError) as exc:
                if not self._peer_lost(sock, exc):
                    return
                # the redial's resync replayed every retained item —
                # including this one — so do NOT resend it here
        # parent queue drained: graceful-stop sentinel; the terminal
        # `stopped` reply (which the receiver turns into _done) is sent
        # only after the remote worker joined, so every published epoch
        # has already crossed back FIFO before join() returns
        while not (self._done.is_set() or self._hard_stop):
            with self._retain_lock:
                sock = self._sock
            try:
                self._send_on(sock, ("stop", True))
                return
            except (ConnectionError, TimeoutError, OSError) as exc:
                if not self._peer_lost(sock, exc):
                    return

    def _receive_loop(self) -> None:
        sock = self._sock
        while True:
            with self._fail_lock:
                if (self._done.is_set() or self._redialing
                        or sock is not self._sock):
                    # a redial is superseding this connection: stop
                    # dispatching NOW, so no old-session publish can be
                    # adopted after the replay set is frozen
                    self._rx_quiesced.set()
                    return
            try:
                msg = wire.recv_message(
                    sock, poll_s=0.2,
                    frame_deadline_s=self.frame_deadline_s)
            except (ConnectionError, TimeoutError, OSError,
                    wire.WireError) as exc:
                # TCP delivers everything the peer flushed before dying —
                # this loop has already dispatched it; the link is dead
                self._rx_quiesced.set()
                self._peer_lost(sock, exc)
                return
            if msg is None:
                continue
            if not self._handle_guarded(sock, msg):
                return

    def _handle_guarded(self, sock, msg) -> bool:
        """Parent-side dispatch failure (e.g. on_publish raising) mirrors
        ProcessWorker: fail the handle, tear the link down, ALWAYS set
        ``_done`` so join() can never hang on a swallowed error.  Transport
        errors raised FROM a dispatch (a resync request hitting a dying
        link) are peer loss, not a parent-side bug — they take the redial
        path like any other dead-peer signal."""
        try:
            dispatch_parent_message(self, msg)
            return True
        except (ConnectionError, TimeoutError, OSError) as exc:
            self._rx_quiesced.set()
            self._peer_lost(sock, exc)
            return False
        except BaseException as exc:
            import traceback

            with self._fail_lock:
                if not self._done.is_set():
                    self.error = exc
                    self.error_tb = traceback.format_exc()
                    self.state = FAILED
                    self.close_transport()
                    if self.process is not None and self.process.is_alive():
                        self.process.terminate()
                    self._ready.set()
                    self._ckpt_event.set()
                    self._done.set()
            return False

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self, timeout: float = 300.0) -> str:
        """Ask the remote worker for a synchronous checkpoint; returns its
        path (which is only meaningful on a shared filesystem — for the
        loopback self-host placement it always is)."""
        with self._ckpt_lock:
            if self._done.is_set() or not self._connected.is_set():
                raise RuntimeError(
                    f"socket worker for {self.tenant.key.tenant_id} is not "
                    "connected; cannot checkpoint")
            self._ckpt_event.clear()
            self._ckpt_result = None
            try:
                self._send(("checkpoint",))
            except (ConnectionError, TimeoutError, OSError) as exc:
                self._finalize_dead_peer(exc)
                raise RuntimeError(
                    f"socket worker for {self.tenant.key.tenant_id} lost "
                    "its peer; cannot checkpoint") from exc
            if not self._ckpt_event.wait(timeout):
                raise TimeoutError(
                    "remote worker did not acknowledge checkpoint")
            res = self._ckpt_result
        if res is None:  # terminal state raced the request
            raise RuntimeError(
                f"socket worker for {self.tenant.key.tenant_id} stopped "
                f"before checkpointing (state={self.state})")
        if "error" in res:
            raise RuntimeError(f"remote checkpoint failed: {res['error']}")
        return res["path"]

    # ---------------------------------------------------------------- reports
    @property
    def ingested_edges(self) -> int:
        return int((self._last_metrics or {}).get("ingested_edges", 0))

    def health(self) -> dict:
        return {
            "state": self.state,
            "alive": self.is_alive(),
            "error": repr(self.error) if self.error else None,
            "epoch": self.tenant.epoch,
            "ingested_offset": self._ingested_offset,
            "queue_depth": self.queue.depth(),
            "peer": (self.address if self.address is not None
                     else ("self-host",
                           self.process.pid if self.process else None)),
        }

    def metrics_snapshot(self) -> dict:
        qstats = self.queue.stats()
        if self._last_metrics is None:
            m = self._fallback_metrics.snapshot(
                queue_stats=qstats, state=self.state,
                epoch=self.tenant.epoch)
            child_depth = 0
        else:
            m = dict(self._last_metrics)
            child_depth = int(m.get("queue_depth", 0))
        # queue accounting is parent-authoritative, same as every backend
        m["state"] = self.state
        m["epoch"] = self.tenant.epoch
        m["queue_depth"] = qstats["depth"] + child_depth
        m["ingest_lag_batches"] = m["queue_depth"]
        m["dropped_batches"] = qstats["dropped_batches"]
        m["dropped_edges"] = qstats["dropped_edges"]
        m["spilled_batches"] = qstats["spilled_batches"]
        m["max_queue_depth"] = qstats["max_depth_seen"]
        m["peer"] = (f"{self.address[0]}:{self.address[1]}"
                     if self.address is not None else "self-host")
        return m


class SocketBackend(ExecutionBackend):
    """Workers across TCP: self-hosted loopback children by default, or
    ``stream_ingest --listen`` hosts via ``addresses``."""

    name = "socket"
    remote = True

    def __init__(self, *, addresses=None, warm_shapes: bool = True,
                 child_env: dict | None = None, mp_context: str = "spawn",
                 connect_timeout_s: float = 300.0,
                 frame_deadline_s: float = 120.0,
                 auth_token: str | None = None,
                 publish_mode: str = "delta") -> None:
        self.auth_token = wire.resolve_auth_token(auth_token)
        self.addresses = list(addresses) if addresses else None
        self._next_addr = 0
        self.warm_shapes = warm_shapes
        self.child_env = dict(child_env or {})
        self._ctx = multiprocessing.get_context(mp_context)
        self.connect_timeout_s = connect_timeout_s
        self.frame_deadline_s = frame_deadline_s
        # "delta" ships per-epoch sketch deltas (sparse-encoded); "full"
        # ships whole fronts — kept selectable for the A/B bench column
        self.publish_mode = publish_mode
        self._workers: list[SocketWorker] = []

    @classmethod
    def from_spec(cls, spec: str) -> "SocketBackend":
        """``"socket"`` (self-host) or ``"socket:HOST:PORT[,HOST:PORT...]"``."""
        if spec == "socket":
            return cls()
        body = spec[len("socket:"):]
        addresses = [wire.parse_hostport(part)
                     for part in body.split(",") if part]
        if not addresses:
            raise ValueError(f"no worker addresses in backend spec {spec!r}")
        return cls(addresses=addresses)

    def make_worker(self, tenant, queue, policy, *, reservoir=None,
                    checkpoint_dir=None, checkpoint_every=0, on_publish=None,
                    poll_s=0.05, coalesce_batches=1, coalesce_target=8192,
                    queue_capacity=64, dedup=False):
        address = None
        if self.addresses is not None:
            address = self.addresses[self._next_addr % len(self.addresses)]
            self._next_addr += 1
        worker = SocketWorker(
            tenant, queue, policy, address=address, reservoir=reservoir,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            on_publish=on_publish, poll_s=poll_s,
            coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, queue_capacity=queue_capacity,
            warm_shapes=self.warm_shapes, child_env=self.child_env,
            ctx=self._ctx, connect_timeout_s=self.connect_timeout_s,
            frame_deadline_s=self.frame_deadline_s,
            auth_token=self.auth_token, publish_mode=self.publish_mode,
            dedup=dedup)
        self._workers.append(worker)
        return worker

    def shutdown(self) -> None:
        """Close listeners and cancel pending dials so no worker can sit in
        accept/connect while ``Runtime.stop()`` waits on joins.  Established
        connections are left alone — draining workers still need them."""
        for w in self._workers:
            w.abort_connect()
            if w._connected.is_set():
                w.close_listener()
            # not yet connected: the starter thread observes the cancel and
            # finalizes the handle itself (listener close included)
