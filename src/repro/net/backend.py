"""Parent-side socket execution backend (DESIGN.md §Net).

``SocketBackend`` is the third ``ExecutionBackend``: the same
``run_ingest_worker`` loop the process backend runs in a spawn child, but
across a TCP connection, framed by the shared ``repro.net.wire`` codec.
Everything the runtime contract demands stays parent-side and
transport-invariant: the ``BoundedEdgeQueue`` (ALL backpressure / drop /
spill accounting), ``SnapshotBuffer.adopt_published`` (epoch ordering),
checkpoint orchestration, and conservation reports.

Two placements per worker:

  self-hosted  (default, no addresses) the parent binds a loopback
               listener on an ephemeral port and spawns a child process
               that dials back and serves one worker session — one
               command, real TCP end-to-end;
  remote       (``SocketBackend(addresses=[...])`` or the
               ``"socket:HOST:PORT,..."`` spec) the parent dials
               ``stream_ingest --listen`` worker hosts, round-robin over
               the address list.

Lifecycle is hang-free by construction: accept/dial loops poll a cancel
event (set by ``request_stop`` and ``SocketBackend.shutdown()``, which
``Runtime.stop()`` invokes before joining), every read/write carries a
frame deadline, and a dead TCP peer surfaces as a FAILED worker whose
error carries the last-known accounting — so ``Runtime.stop()`` raises
``WorkerFailure`` with the final report attached, mirroring the process
backend's SIGKILL semantics.
"""
from __future__ import annotations

import multiprocessing
import socket
import threading
import time

from repro.net import wire
from repro.net.ingest_server import _selfhost_worker_main
from repro.runtime.backend import (
    ExecutionBackend,
    build_child_spec,
    dispatch_parent_message,
)
from repro.runtime.metrics import WorkerMetrics
from repro.runtime.worker import CREATED, DRAINING, FAILED, RUNNING, STOPPED


class SocketWorker:
    """Parent-side handle for one ingest worker across a TCP connection.

    Quacks like ``IngestWorker``/``ProcessWorker`` for everything the
    supervisor touches.  Three parent threads cooperate, exactly as in the
    process backend: a *starter* establishes the connection (accept or
    dial) and sends the ``hello`` spec, the *forwarder* moves ``QueueItem``
    frames from the parent's bounded queue onto the socket, and the
    *receiver* adopts published epochs into the parent ``SnapshotBuffer``.
    """

    def __init__(self, tenant, queue, policy, *, address=None,
                 reservoir=None, checkpoint_dir=None, checkpoint_every=0,
                 on_publish=None, poll_s=0.05, coalesce_batches=1,
                 coalesce_target=8192, queue_capacity=64, warm_shapes=True,
                 child_env=None, ctx=None, connect_timeout_s=300.0,
                 frame_deadline_s=120.0, auth_token=None) -> None:
        import jax

        self.tenant = tenant
        self.queue = queue
        self.on_publish = on_publish
        self.reservoir = reservoir  # kept live from shipped publish state
        self.state = CREATED
        self.error: BaseException | None = None
        self.error_tb: str | None = None
        self.base_edges = (tenant.snapshot.n_edges
                          + tenant.buffer.pending_edges)
        self.poll_s = poll_s
        self.frame_deadline_s = frame_deadline_s
        self.connect_timeout_s = connect_timeout_s
        self._treedef = jax.tree_util.tree_structure(tenant.snapshot.sketch)
        self._spec = build_child_spec(
            tenant, policy, reservoir=reservoir,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            poll_s=poll_s, coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, queue_capacity=queue_capacity,
            warm_shapes=warm_shapes, env=dict(child_env or {}))
        self.auth_token = wire.resolve_auth_token(auth_token)
        self.address = address  # None ⇒ self-hosted loopback child
        self._sock: socket.socket | None = None
        self._send_lock = threading.Lock()  # forwarder vs checkpoint vs stop
        self._listener: socket.socket | None = None
        self.process = None
        if address is None:
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.bind(("127.0.0.1", 0))
            self._listener.listen(1)
            host, port = self._listener.getsockname()[:2]
            ctx = ctx or multiprocessing.get_context("spawn")
            self.process = ctx.Process(
                target=_selfhost_worker_main,
                args=(host, port, dict(child_env or {})),
                daemon=True, name=f"ingest-sock-{tenant.key.tenant_id}")
        self._ingested_offset = tenant.offset - 1
        self._last_metrics: dict | None = None
        self._fallback_metrics = WorkerMetrics()
        self._ready = threading.Event()
        self._connected = threading.Event()
        self._done = threading.Event()
        self._stop_event = threading.Event()
        self._abort_connect = threading.Event()
        self._fail_lock = threading.Lock()
        self._drain = True
        self._hard_stop = False
        self._started = False
        self._ckpt_lock = threading.Lock()
        self._ckpt_event = threading.Event()
        self._ckpt_result: dict | None = None

    # -------------------------------------------------------------- lifecycle
    def start(self) -> None:
        """Non-blocking: connection establishment happens in a starter
        thread so ``Runtime.start()`` brings K workers up concurrently."""
        self._started = True
        self.state = RUNNING
        threading.Thread(target=self._connect_and_attach, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-dial").start()

    def _accept_selfhost(self) -> socket.socket:
        self.process.start()
        self._listener.settimeout(0.5)
        deadline = time.monotonic() + self.connect_timeout_s
        while time.monotonic() < deadline:
            if self._abort_connect.is_set():
                raise ConnectionAbortedError(
                    "worker accept cancelled by stop/shutdown")
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                if not self.process.is_alive():
                    raise ConnectionError(
                        "self-hosted socket worker died before dialing back "
                        f"(exitcode={self.process.exitcode})") from None
                continue
            except OSError as exc:
                raise ConnectionAbortedError(
                    f"worker listener closed before the worker connected "
                    f"({exc!r})") from exc
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return conn
        raise TimeoutError(
            f"self-hosted worker did not dial back within "
            f"{self.connect_timeout_s}s")

    def _connect_and_attach(self) -> None:
        try:
            if self.address is None:
                sock = self._accept_selfhost()
            else:
                sock = wire.connect_with_retry(
                    self.address, deadline_s=self.connect_timeout_s,
                    stop=self._abort_connect)
            self.close_listener()  # one peer per worker; stop accepting
            with self._send_lock:
                if self.address is not None and self.auth_token:
                    # remote worker host: present the shared token before
                    # the hello (hosts without one ignore the frame)
                    wire.send_message(sock, ("auth", self.auth_token),
                                      deadline_s=self.frame_deadline_s)
                wire.send_message(sock, ("hello", self._spec),
                                  deadline_s=self.frame_deadline_s)
            self._sock = sock
        except BaseException as exc:
            import traceback

            if self._hard_stop or (self._stop_event.is_set()
                                   and self._abort_connect.is_set()):
                self.state = STOPPED  # stop cancelled the dial; not a crash
            else:
                self.error = exc
                self.error_tb = traceback.format_exc()
                self.state = FAILED
            self.close_transport()
            self._ready.set()
            self._ckpt_event.set()
            self._done.set()
            return
        self._connected.set()
        if self._hard_stop:  # killed while dialing; tear the link down
            self.close_transport()
            self._finalize_dead_peer(None)
            return
        threading.Thread(target=self._receive_loop, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-rcv").start()
        threading.Thread(target=self._forward_loop, daemon=True,
                         name=f"sock-{self.tenant.key.tenant_id}-fwd").start()

    def wait_ready(self, timeout: float = 300.0) -> bool:
        ok = self._ready.wait(timeout)
        if self.state == FAILED:
            raise RuntimeError(
                f"socket worker for {self.tenant.key.tenant_id} failed "
                f"during startup: {self.error}\n{self.error_tb or ''}")
        return ok

    def request_stop(self, drain: bool = True) -> None:
        self._drain = drain
        self._stop_event.set()
        if drain:
            if self.state == RUNNING:
                self.state = DRAINING
        else:
            # crash-like hard stop, PR 5 SIGKILL semantics: abandon
            # in-queue and in-flight work; restore replays from checkpoint
            self._hard_stop = True
            self._abort_connect.set()
            self.queue.close()
            self.close_transport()
            if self.process is not None and self.process.is_alive():
                self.process.terminate()
            if not self._connected.is_set():
                self._done.set()  # starter owns the rest of the teardown

    def join(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout

        def remaining(default=None):
            if deadline is None:
                return default
            return max(deadline - time.monotonic(), 0.01)

        self._done.wait(timeout=remaining())
        if self.process is not None and self.process.is_alive():
            self.process.join(timeout=remaining(60.0))
        self.close_transport()

    def is_alive(self) -> bool:
        return self._started and not self._done.is_set()

    # -------------------------------------------------------- transport utils
    def close_listener(self) -> None:
        """Close the self-host accept listener (idempotent).  Called once a
        peer is attached, by hard stops, and by ``SocketBackend.shutdown()``
        so ``Runtime.stop()`` never joins against a worker stuck in
        accept."""
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass

    def close_transport(self) -> None:
        self.close_listener()
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass

    def abort_connect(self) -> None:
        """Cancel a pending dial/accept (used by backend shutdown)."""
        self._abort_connect.set()

    def _accounting_tail(self) -> str:
        m = self._last_metrics or {}
        return ("last-known accounting: "
                f"ingested_edges={m.get('ingested_edges', 0)}, "
                f"ingested_batches={m.get('ingested_batches', 0)}, "
                f"published_epochs={m.get('published_epochs', 0)}, "
                f"epoch={self.tenant.epoch}, "
                f"ingested_offset={self._ingested_offset}")

    def _finalize_dead_peer(self, exc: BaseException | None) -> None:
        """The TCP peer is gone without a terminal message (or we tore it
        down).  Mirrors ``ProcessWorker._finalize_death``: hard stops read
        as STOPPED, anything else is a FAILED worker whose error carries
        the last-known accounting so ``WorkerFailure.report`` plus this
        message tell the whole story."""
        with self._fail_lock:
            if self._done.is_set():
                return
            if self._hard_stop:
                self.state = STOPPED
            else:
                detail = f" ({exc!r})" if exc is not None else ""
                self.error = ConnectionError(
                    f"socket worker for {self.tenant.key.tenant_id} lost its "
                    f"TCP peer{detail}; {self._accounting_tail()}")
                self.error_tb = None
                self.state = FAILED
            self.close_transport()
            if self.process is not None and self.process.is_alive():
                self.process.terminate()
            self._ready.set()
            self._ckpt_event.set()
            self._done.set()

    def _send(self, msg) -> None:
        with self._send_lock:
            wire.send_message(self._sock, msg,
                              deadline_s=self.frame_deadline_s)

    # -------------------------------------------------------------- transport
    def _forward_loop(self) -> None:
        while not self._ready.wait(timeout=0.1):
            if self._done.is_set() or self._hard_stop:
                return
        try:
            while True:
                if self._done.is_set() or self._hard_stop:
                    return
                item = self.queue.get(timeout=self.poll_s)
                if item is None:
                    if (self._stop_event.is_set() and self._drain
                            and self.queue.depth() == 0):
                        break
                    continue
                self._send(("item", item.offset, item.src, item.dst,
                            item.weight, item.n_edges, item.trace_id))
            # parent queue drained: graceful-stop sentinel; the terminal
            # `stopped` reply (which the receiver turns into _done) is sent
            # only after the remote worker joined, so every published epoch
            # has already crossed back FIFO before join() returns
            if not (self._done.is_set() or self._hard_stop):
                self._send(("stop", True))
        except (ConnectionError, TimeoutError, OSError) as exc:
            self._finalize_dead_peer(exc)

    def _receive_loop(self) -> None:
        while True:
            try:
                msg = wire.recv_message(
                    self._sock, poll_s=0.2,
                    frame_deadline_s=self.frame_deadline_s)
            except (ConnectionError, TimeoutError, OSError,
                    wire.WireError) as exc:
                # TCP delivers everything the peer flushed before dying, so
                # unlike the process pipe there is no tail left to adopt
                self._finalize_dead_peer(exc)
                return
            if msg is None:
                if self._done.is_set():
                    return
                continue
            if not self._handle_guarded(msg):
                return
            if self._done.is_set():
                return

    def _handle_guarded(self, msg) -> bool:
        """Parent-side dispatch failure (e.g. on_publish raising) mirrors
        ProcessWorker: fail the handle, tear the link down, ALWAYS set
        ``_done`` so join() can never hang on a swallowed error."""
        try:
            dispatch_parent_message(self, msg)
            return True
        except BaseException as exc:
            import traceback

            with self._fail_lock:
                if not self._done.is_set():
                    self.error = exc
                    self.error_tb = traceback.format_exc()
                    self.state = FAILED
                    self.close_transport()
                    if self.process is not None and self.process.is_alive():
                        self.process.terminate()
                    self._ready.set()
                    self._ckpt_event.set()
                    self._done.set()
            return False

    # ------------------------------------------------------------- checkpoint
    def checkpoint(self, timeout: float = 300.0) -> str:
        """Ask the remote worker for a synchronous checkpoint; returns its
        path (which is only meaningful on a shared filesystem — for the
        loopback self-host placement it always is)."""
        with self._ckpt_lock:
            if self._done.is_set() or not self._connected.is_set():
                raise RuntimeError(
                    f"socket worker for {self.tenant.key.tenant_id} is not "
                    "connected; cannot checkpoint")
            self._ckpt_event.clear()
            self._ckpt_result = None
            try:
                self._send(("checkpoint",))
            except (ConnectionError, TimeoutError, OSError) as exc:
                self._finalize_dead_peer(exc)
                raise RuntimeError(
                    f"socket worker for {self.tenant.key.tenant_id} lost "
                    "its peer; cannot checkpoint") from exc
            if not self._ckpt_event.wait(timeout):
                raise TimeoutError(
                    "remote worker did not acknowledge checkpoint")
            res = self._ckpt_result
        if res is None:  # terminal state raced the request
            raise RuntimeError(
                f"socket worker for {self.tenant.key.tenant_id} stopped "
                f"before checkpointing (state={self.state})")
        if "error" in res:
            raise RuntimeError(f"remote checkpoint failed: {res['error']}")
        return res["path"]

    # ---------------------------------------------------------------- reports
    @property
    def ingested_edges(self) -> int:
        return int((self._last_metrics or {}).get("ingested_edges", 0))

    def health(self) -> dict:
        return {
            "state": self.state,
            "alive": self.is_alive(),
            "error": repr(self.error) if self.error else None,
            "epoch": self.tenant.epoch,
            "ingested_offset": self._ingested_offset,
            "queue_depth": self.queue.depth(),
            "peer": (self.address if self.address is not None
                     else ("self-host",
                           self.process.pid if self.process else None)),
        }

    def metrics_snapshot(self) -> dict:
        qstats = self.queue.stats()
        if self._last_metrics is None:
            m = self._fallback_metrics.snapshot(
                queue_stats=qstats, state=self.state,
                epoch=self.tenant.epoch)
            child_depth = 0
        else:
            m = dict(self._last_metrics)
            child_depth = int(m.get("queue_depth", 0))
        # queue accounting is parent-authoritative, same as every backend
        m["state"] = self.state
        m["epoch"] = self.tenant.epoch
        m["queue_depth"] = qstats["depth"] + child_depth
        m["ingest_lag_batches"] = m["queue_depth"]
        m["dropped_batches"] = qstats["dropped_batches"]
        m["dropped_edges"] = qstats["dropped_edges"]
        m["spilled_batches"] = qstats["spilled_batches"]
        m["max_queue_depth"] = qstats["max_depth_seen"]
        m["peer"] = (f"{self.address[0]}:{self.address[1]}"
                     if self.address is not None else "self-host")
        return m


class SocketBackend(ExecutionBackend):
    """Workers across TCP: self-hosted loopback children by default, or
    ``stream_ingest --listen`` hosts via ``addresses``."""

    name = "socket"
    remote = True

    def __init__(self, *, addresses=None, warm_shapes: bool = True,
                 child_env: dict | None = None, mp_context: str = "spawn",
                 connect_timeout_s: float = 300.0,
                 frame_deadline_s: float = 120.0,
                 auth_token: str | None = None) -> None:
        self.auth_token = wire.resolve_auth_token(auth_token)
        self.addresses = list(addresses) if addresses else None
        self._next_addr = 0
        self.warm_shapes = warm_shapes
        self.child_env = dict(child_env or {})
        self._ctx = multiprocessing.get_context(mp_context)
        self.connect_timeout_s = connect_timeout_s
        self.frame_deadline_s = frame_deadline_s
        self._workers: list[SocketWorker] = []

    @classmethod
    def from_spec(cls, spec: str) -> "SocketBackend":
        """``"socket"`` (self-host) or ``"socket:HOST:PORT[,HOST:PORT...]"``."""
        if spec == "socket":
            return cls()
        body = spec[len("socket:"):]
        addresses = [wire.parse_hostport(part)
                     for part in body.split(",") if part]
        if not addresses:
            raise ValueError(f"no worker addresses in backend spec {spec!r}")
        return cls(addresses=addresses)

    def make_worker(self, tenant, queue, policy, *, reservoir=None,
                    checkpoint_dir=None, checkpoint_every=0, on_publish=None,
                    poll_s=0.05, coalesce_batches=1, coalesce_target=8192,
                    queue_capacity=64):
        address = None
        if self.addresses is not None:
            address = self.addresses[self._next_addr % len(self.addresses)]
            self._next_addr += 1
        worker = SocketWorker(
            tenant, queue, policy, address=address, reservoir=reservoir,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            on_publish=on_publish, poll_s=poll_s,
            coalesce_batches=coalesce_batches,
            coalesce_target=coalesce_target, queue_capacity=queue_capacity,
            warm_shapes=self.warm_shapes, child_env=self.child_env,
            ctx=self._ctx, connect_timeout_s=self.connect_timeout_s,
            frame_deadline_s=self.frame_deadline_s,
            auth_token=self.auth_token)
        self._workers.append(worker)
        return worker

    def shutdown(self) -> None:
        """Close listeners and cancel pending dials so no worker can sit in
        accept/connect while ``Runtime.stop()`` waits on joins.  Established
        connections are left alone — draining workers still need them."""
        for w in self._workers:
            w.abort_connect()
            if w._connected.is_set():
                w.close_listener()
            # not yet connected: the starter thread observes the cancel and
            # finalizes the handle itself (listener close included)
