"""Front-end query server with admission control (DESIGN.md §Net).

Clients open plain TCP connections speaking the ``repro.net.wire`` framing
and send ``query`` frames carrying pickled ``serving.engine.Request``
lists.  A single executor thread coalesces everything that arrived across
ALL connections into one ``QueryEngine.execute`` call (the pad-to-bucket
planner was built for exactly this: heterogeneous batches, few shapes), so
concurrency raises batch occupancy instead of contending on the engine.
The executor never touches a socket: replies are handed to per-connection
bounded writer queues, each drained by its own thread — a client that
stops reading its socket stalls (and eventually loses) only its OWN
connection, never the shared executor or other tenants' replies.

Admission control happens BEFORE a request can queue:

  too-large      a frame carrying more requests than could EVER be
                 admitted (``> max_inflight``, or ``> tenant_burst`` when
                 rate limiting is on) is rejected as ``too_large`` with
                 the applicable limit — not with a retry hint that could
                 never come true;
  token bucket   per-tenant rate limit (``tenant_qps``/``tenant_burst``):
                 a tenant above its rate is rejected with
                 ``rate_limited`` + a retry-after hint sized to when its
                 bucket refills — one hot tenant cannot starve the rest;
  in-flight cap  a global bounded budget (``max_inflight`` REQUESTS queued
                 or executing): past it, requests are fast-rejected with
                 ``overloaded`` + a retry-after hint from the measured
                 per-request service EWMA — overload degrades into an
                 accounted shed rate with bounded latency for admitted
                 work, never into an unbounded queue.

Every shed is counted in ``stats()`` (``shed_overload`` /
``shed_rate_limited`` / ``shed_too_large``); ``offered == admitted +
shed`` always — a request is either answered, errored, or visibly
rejected, never silently dropped.

Security: the wire decodes through the restricted unpickler, non-loopback
binds require a shared auth token (``wire.check_bind_allowed``), and with
a token configured every connection must open with an ``auth`` frame
before anything else is honoured.

Answers are epoch-stamped (the snapshot epoch they were computed against)
so a client can detect staleness against the ingest frontier it expects.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import socket
import threading
import time
from collections import deque
from typing import Callable

from repro.net import wire
from repro.net.ingest_server import scrape_payload
from repro.obs.hub import get_hub
from repro.obs.trace import get_trace_log, new_trace_id


class TokenBucket:
    """Classic token bucket; ``take`` returns 0.0 on success or the time
    until enough tokens accrue (the retry-after hint)."""

    def __init__(self, rate: float, burst: float) -> None:
        assert rate > 0 and burst > 0
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last = time.monotonic()

    def take(self, n: float = 1.0) -> float:
        now = time.monotonic()
        self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
        self._last = now
        if self.tokens >= n:
            self.tokens -= n
            return 0.0
        return (n - self.tokens) / self.rate


@dataclasses.dataclass
class _Call:
    """One admitted query frame waiting for the executor."""

    send: Callable[[tuple], None]
    req_id: int
    requests: list
    trace_id: str = ""       # span minted at accept (repro.obs.trace)
    accepted_at: float = 0.0  # perf_counter at admission


class _ConnWriter:
    """Bounded per-connection reply writer.

    ``send`` enqueues and returns immediately; a dedicated thread does the
    actual socket writes under the frame deadline.  If the queue overflows
    (client stopped reading) or a write stalls past its deadline, the
    connection is torn down and every later ``send`` raises
    ``ConnectionError`` — the slow client pays, nobody else waits.
    """

    def __init__(self, conn: socket.socket, *, deadline_s: float,
                 max_pending: int, name: str) -> None:
        self._conn = conn
        self._deadline_s = deadline_s
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=max_pending)
        self._dead = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=name)
        self._thread.start()

    def send(self, msg: tuple) -> None:
        if self._dead.is_set():
            raise ConnectionError("reply writer closed")
        try:
            self._q.put_nowait(msg)
        except queue_mod.Full:
            self.kill()
            raise ConnectionError(
                "client stopped reading: reply queue overflowed, "
                "connection dropped") from None

    def kill(self) -> None:
        """Tear the connection down; also unblocks the connection's reader."""
        self._dead.set()
        try:
            self._conn.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._conn.close()
        except OSError:
            pass

    def close(self) -> None:
        """Stop the writer (pending replies to a gone client are dropped)."""
        self._dead.set()

    def _loop(self) -> None:
        while True:
            try:
                msg = self._q.get(timeout=0.2)
            except queue_mod.Empty:
                if self._dead.is_set():
                    return
                continue
            try:
                wire.send_message(self._conn, msg,
                                  deadline_s=self._deadline_s)
            except (ConnectionError, TimeoutError, OSError):
                self.kill()
                return


class Rejected(RuntimeError):
    """Client-side view of an admission rejection."""

    def __init__(self, reason: str, retry_after_ms: float) -> None:
        self.reason = reason
        self.retry_after_ms = retry_after_ms
        super().__init__(f"rejected ({reason}); retry after "
                         f"{retry_after_ms:.1f} ms")


class QueryServer:
    """Coalescing TCP front-end over one ``QueryEngine``.

    ``engine`` only needs an ``execute(snapshot, requests) -> list[Result]``
    — the plain ``QueryEngine`` and ``ShardedQueryEngine`` both qualify.
    ``snapshot_fn`` is polled per batch, so a concurrently-ingesting tenant
    serves fresh epochs mid-run (same contract as ``OpenLoopLoadGen``).
    """

    def __init__(self, engine, snapshot_fn, *, host: str = "127.0.0.1",
                 port: int = 0, max_inflight: int = 4096,
                 batch_max: int = 1024, tenant_qps: float = 0.0,
                 tenant_burst: float | None = None,
                 info: dict | None = None,
                 frame_deadline_s: float = 60.0,
                 auth_token: str | None = None,
                 reply_queue_max: int = 256) -> None:
        self.engine = engine
        self.snapshot_fn = snapshot_fn
        self.max_inflight = int(max_inflight)
        self.batch_max = int(batch_max)
        self.tenant_qps = float(tenant_qps)  # 0 ⇒ rate limiting off
        self.tenant_burst = float(tenant_burst if tenant_burst is not None
                                  else max(1.0, tenant_qps))
        self.info = dict(info or {})
        self.frame_deadline_s = frame_deadline_s
        self.auth_token = wire.resolve_auth_token(auth_token)
        self.reply_queue_max = int(reply_queue_max)
        wire.check_bind_allowed(host, self.auth_token, "QueryServer")
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(256)
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self._cv = threading.Condition()
        self._pending: deque[_Call] = deque()  # guarded-by: _cv
        self._inflight = 0  # admitted, not yet answered; guarded-by: _cv
        self._buckets: dict[str, TokenBucket] = {}  # guarded-by: _cv
        self._service_ewma_ms = 1.0  # service-time est.; guarded-by: _cv
        self._stats = {  # guarded-by: _cv
            "offered_requests": 0,
            "admitted_requests": 0,
            "served_requests": 0,
            "errored_requests": 0,
            "shed_overload": 0,
            "shed_rate_limited": 0,
            "shed_too_large": 0,
            "auth_failures": 0,
            "batches": 0,
            "max_batch": 0,
            "connections": 0,
        }
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._conns: list[socket.socket] = []
        self._trace = get_trace_log()
        # typed instruments: per-request accept->reply latency and batch
        # occupancy live in mergeable histograms; the admission ledger is
        # mirrored into hub counters by a scrape-time collector so the
        # admission hot path pays nothing extra
        hub = get_hub()
        self._hub_latency = hub.histogram(
            "repro_query_latency_seconds",
            "per-request accept->reply latency")
        self._hub_batch = hub.histogram(
            "repro_query_batch_requests",
            "requests coalesced per executor batch", ladder="size")

    def _collect_hub(self) -> None:
        """Scrape-time mirror of the admission ledger into hub counters —
        exact parity with ``stats()`` at every scrape."""
        s = self.stats()
        hub = get_hub()
        for key in ("offered_requests", "admitted_requests",
                    "served_requests", "errored_requests", "shed_overload",
                    "shed_rate_limited", "shed_too_large", "auth_failures",
                    "batches", "connections"):
            hub.counter(f"repro_query_{key}_total",
                        f"query server ledger: {key}").set(s[key])
        hub.gauge("repro_query_inflight",
                  "admitted requests not yet answered").set(s["inflight"])
        hub.gauge("repro_query_service_ewma_ms",
                  "per-request service time estimate"
                  ).set(s["service_ewma_ms"])

    # -------------------------------------------------------------- lifecycle
    def start(self) -> "QueryServer":
        get_hub().add_collector(self._collect_hub)
        acceptor = threading.Thread(target=self._accept_loop, daemon=True,
                                    name="query-accept")
        executor = threading.Thread(target=self._execute_loop, daemon=True,
                                    name="query-exec")
        self._threads = [acceptor, executor]
        acceptor.start()
        executor.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        self._collect_hub()  # freeze final ledger values, then detach
        get_hub().remove_collector(self._collect_hub)
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._cv:
            self._cv.notify_all()
        for conn in list(self._conns):
            try:
                conn.close()
            except OSError:
                pass
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(deadline - time.monotonic(), 0.01))

    def stats(self) -> dict:
        # everything — counters, inflight AND the ewma — reads under the
        # lock the executor writes them under, so a stats() snapshot is
        # internally consistent, never torn against the counters
        with self._cv:
            s = dict(self._stats)
            s["inflight"] = self._inflight
            s["service_ewma_ms"] = round(self._service_ewma_ms, 4)
        return s

    # ----------------------------------------------------------- connections
    def _accept_loop(self) -> None:
        self._listener.settimeout(0.2)
        while not self._stop.is_set():
            try:
                conn, peer = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed by stop()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conns.append(conn)
            with self._cv:
                self._stats["connections"] += 1
            t = threading.Thread(target=self._client_loop, args=(conn,),
                                 daemon=True,
                                 name=f"query-client-{peer[0]}:{peer[1]}")
            self._threads.append(t)
            t.start()

    def _client_loop(self, conn: socket.socket) -> None:
        try:
            peer = conn.getpeername()
        except OSError:
            peer = ("?", 0)
        writer = _ConnWriter(conn, deadline_s=self.frame_deadline_s,
                             max_pending=self.reply_queue_max,
                             name=f"query-write-{peer[0]}:{peer[1]}")
        send = writer.send
        authed = not self.auth_token
        try:
            while not self._stop.is_set():
                msg = wire.recv_message(conn, poll_s=0.2,
                                        frame_deadline_s=self.frame_deadline_s)
                if msg is None:
                    continue
                kind = msg[0]
                if kind == "auth":
                    # tolerated (and ignored) when no token is configured,
                    # so clients may always present their token
                    if self.auth_token and not wire.auth_matches(
                            self.auth_token, msg[1] if len(msg) > 1 else None):
                        break  # counted below; never name which part failed
                    authed = True
                    continue
                if not authed:
                    break
                if kind == "query":
                    self._admit(send, msg[1])
                elif kind == "metrics_req":
                    # scrape surface; sits behind the same auth gate as
                    # query frames (the `authed` check above)
                    send(("metrics", scrape_payload()))
                elif kind == "info_req":
                    snap = self.snapshot_fn()
                    send(("info", {**self.info, "epoch": snap.epoch,
                                   "n_edges": snap.n_edges,
                                   "stats": self.stats()}))
                elif kind == "ping":
                    send(("pong",))
                else:
                    send(("error", {"error": f"unexpected frame {kind!r}"}))
            else:
                authed = True  # server stop, not an auth problem
            if not authed:
                with self._cv:
                    self._stats["auth_failures"] += 1
                try:
                    send(("error", {"error": "auth required"}))
                except (ConnectionError, TimeoutError, OSError):
                    pass
        except (ConnectionError, TimeoutError, OSError, wire.WireError):
            pass  # client went away (or spoke junk); its session only
        finally:
            writer.close()
            try:
                conn.close()
            except OSError:
                pass

    # -------------------------------------------------------------- admission
    def _retry_after_ms(self, n_queued: int) -> float:  # requires-lock: _cv
        # time until the current backlog is worked off, from the measured
        # per-request service EWMA — an honest Retry-After, not a constant
        return max(1.0, n_queued * self._service_ewma_ms)

    def _admit(self, send, payload: dict) -> None:
        req_id = payload.get("id", 0)
        tenant = str(payload.get("tenant", "default"))
        requests = list(payload.get("requests", ()))
        n = len(requests)
        # a frame bigger than the smallest applicable admission ceiling can
        # NEVER succeed: a finite retry-after would be a lie (the token
        # bucket caps at burst; inflight can only reach max_inflight), so
        # it gets a distinct verdict naming the limit instead
        limit = self.max_inflight
        if self.tenant_qps > 0:
            limit = min(limit, int(self.tenant_burst))
        call = None
        with self._cv:
            self._stats["offered_requests"] += n
            if n > limit:
                self._stats["shed_too_large"] += n
                send_now = ("reject", {"id": req_id, "reason": "too_large",
                                       "retry_after_ms": 0.0,
                                       "max_requests": limit})
            elif self.tenant_qps > 0:
                bucket = self._buckets.get(tenant)
                if bucket is None:
                    bucket = TokenBucket(self.tenant_qps, self.tenant_burst)
                    self._buckets[tenant] = bucket
                wait_s = bucket.take(n)
                if wait_s > 0:
                    self._stats["shed_rate_limited"] += n
                    verdict = ("reject", {"id": req_id,
                                          "reason": "rate_limited",
                                          "retry_after_ms": wait_s * 1e3})
                    send_now = verdict
                else:
                    send_now = None
            else:
                send_now = None
            if send_now is None:
                if self._inflight + n > self.max_inflight:
                    self._stats["shed_overload"] += n
                    send_now = ("reject", {
                        "id": req_id, "reason": "overloaded",
                        "retry_after_ms":
                            self._retry_after_ms(self._inflight + n)})
                else:
                    self._inflight += n
                    self._stats["admitted_requests"] += n
                    call = _Call(send, req_id, requests,
                                 trace_id=new_trace_id(),
                                 accepted_at=time.perf_counter())
                    self._pending.append(call)
                    self._cv.notify()
        if send_now is not None:
            send(send_now)
        elif call is not None:
            self._trace.emit(call.trace_id, "query", "accept",
                             tenant=tenant, n_requests=n)

    # --------------------------------------------------------------- executor
    def _take_batch(self) -> list[_Call]:  # requires-lock: _cv
        """Under ``_cv``: pop whole calls up to ``batch_max`` requests (a
        call is never split; the first call always fits by itself)."""
        calls: list[_Call] = []
        total = 0
        while self._pending:
            nxt = len(self._pending[0].requests)
            if calls and total + nxt > self.batch_max:
                break
            call = self._pending.popleft()
            calls.append(call)
            total += nxt
        return calls

    def _execute_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stop.is_set():
                    self._cv.wait(timeout=0.2)
                if self._stop.is_set() and not self._pending:
                    return
                calls = self._take_batch()
            flat = [r for c in calls for r in c.requests]
            for call in calls:
                self._trace.emit(call.trace_id, "query", "plan",
                                 batch=len(flat))
            self._hub_batch.observe(len(flat))
            t0 = time.perf_counter()
            try:
                results = self.engine.execute(self.snapshot_fn(), flat)
                err = None
            except Exception as exc:  # noqa: BLE001 — answer sick, stay up
                results, err = None, repr(exc)
            t1 = time.perf_counter()
            dt_ms = (t1 - t0) * 1e3
            for call in calls:
                self._trace.emit(call.trace_id, "query", "execute",
                                 ms=round(dt_ms, 3), ok=err is None)
            cursor = 0
            for call in calls:
                k = len(call.requests)
                if err is None:
                    part = results[cursor:cursor + k]
                    cursor += k
                    reply = ("result", {
                        "id": call.req_id,
                        "epoch": part[0].epoch if part else None,
                        "values": [r.value for r in part],
                    })
                else:
                    reply = ("error", {"id": call.req_id, "error": err})
                try:
                    # hands off to the connection's writer queue — never a
                    # socket write, so a stalled client cannot block this
                    # loop (it loses its own connection instead)
                    call.send(reply)
                except (ConnectionError, TimeoutError, OSError):
                    pass  # client vanished mid-flight; accounting still runs
                lat_s = time.perf_counter() - call.accepted_at
                self._hub_latency.observe_n(lat_s, k)
                self._trace.emit(call.trace_id, "query", "reply",
                                 ms=round(lat_s * 1e3, 3))
            with self._cv:
                self._inflight -= len(flat)
                if err is None:
                    self._stats["served_requests"] += len(flat)
                    if flat:
                        per_req = dt_ms / len(flat)
                        self._service_ewma_ms += 0.3 * (
                            per_req - self._service_ewma_ms)
                else:
                    self._stats["errored_requests"] += len(flat)
                self._stats["batches"] += 1
                self._stats["max_batch"] = max(self._stats["max_batch"],
                                               len(flat))


# ---------------------------------------------------------------- client --


class QueryClient:
    """Minimal blocking client: one outstanding query per connection (the
    load generator opens one client per connection for concurrency)."""

    def __init__(self, address: tuple[str, int], *, tenant: str = "default",
                 connect_timeout_s: float = 30.0,
                 frame_deadline_s: float = 60.0,
                 auth_token: str | None = None) -> None:
        self.address = tuple(address)
        self.tenant = tenant
        self.frame_deadline_s = frame_deadline_s
        self._sock = wire.connect_with_retry(self.address,
                                             deadline_s=connect_timeout_s)
        self._next_id = 0
        token = wire.resolve_auth_token(auth_token)
        if token:  # must be the first frame; servers without a token ignore it
            wire.send_message(self._sock, ("auth", token),
                              deadline_s=frame_deadline_s)

    def _rpc(self, msg: tuple, *, timeout_s: float | None = None) -> tuple:
        wire.send_message(self._sock, msg, deadline_s=self.frame_deadline_s)
        deadline = time.monotonic() + (timeout_s or self.frame_deadline_s)
        while True:
            reply = wire.recv_message(self._sock, poll_s=0.2,
                                      frame_deadline_s=self.frame_deadline_s)
            if reply is not None:
                return reply
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"no reply to {msg[0]!r} within {timeout_s}s")

    def info(self) -> dict:
        reply = self._rpc(("info_req",))
        if reply[0] != "info":
            raise wire.WireError(f"expected info, got {reply[0]!r}")
        return reply[1]

    def metrics(self) -> dict:
        """Scrape the server's telemetry hub: ``{"prometheus": text,
        "state": merged_state, "ts": ...}``."""
        reply = self._rpc(("metrics_req",))
        if reply[0] != "metrics":
            raise wire.WireError(f"expected metrics, got {reply[0]!r}")
        return reply[1]

    def call(self, requests: list, *, timeout_s: float | None = None) -> dict:
        """Low-level: returns the reply payload dict with a ``"kind"`` key
        (``result`` | ``reject`` | ``error``); never raises on rejection."""
        self._next_id += 1
        reply = self._rpc(("query", {"id": self._next_id,
                                     "tenant": self.tenant,
                                     "requests": list(requests)}),
                          timeout_s=timeout_s)
        kind, payload = reply[0], dict(reply[1])
        if kind not in ("result", "reject", "error"):
            raise wire.WireError(f"unexpected reply frame {kind!r}")
        if payload.get("id") not in (None, self._next_id):
            raise wire.WireError(
                f"reply id {payload.get('id')} does not match request "
                f"{self._next_id} (protocol requires one outstanding query)")
        payload["kind"] = kind
        return payload

    def query(self, requests: list, *, timeout_s: float | None = None):
        """Returns ``(values, epoch)``; raises :class:`Rejected` on an
        admission rejection and ``RuntimeError`` on a server-side error."""
        payload = self.call(requests, timeout_s=timeout_s)
        if payload["kind"] == "reject":
            raise Rejected(payload["reason"], payload["retry_after_ms"])
        if payload["kind"] == "error":
            raise RuntimeError(f"server error: {payload['error']}")
        return payload["values"], payload["epoch"]

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass
