"""Versioned length-prefixed wire protocol (DESIGN.md §Net).

One codec, two transports.  Every message that crosses a worker boundary —
whether over the process backend's multiprocessing pipe or a TCP socket —
is framed as::

    MAGIC(4) | WIRE_VERSION(u16) | FRAME_TYPE(u16) | LENGTH(u32) | PAYLOAD

with the payload a pickled message tuple ``(kind, ...)`` using exactly the
serialization the process backend has always shipped (numpy leaves for
``QueueItem`` batches and snapshot publications).  The header exists so a
version skew or a torn stream fails as a loud :class:`WireError` naming the
mismatch instead of a pickle-level crash deep inside a worker.

Deadline discipline (satellite: no hangs by construction): the socket
receive path separates *idle* from *mid-frame* waiting.  ``recv_message``
polls up to ``poll_s`` for the first byte and returns ``None`` if the peer
is merely quiet, but once a frame has started the remainder must arrive
within ``frame_deadline_s`` or the read raises — a peer that wedges halfway
through a frame can never hang its reader.
"""
from __future__ import annotations

import pickle
import socket
import struct
import time

MAGIC = b"KMTX"
WIRE_VERSION = 1

_HEADER = struct.Struct(">4sHHI")
HEADER_SIZE = _HEADER.size

# A 256 KB sketch budget times a handful of leaves plus pickling overhead is
# well under a megabyte; 1 GiB is a generous ceiling that still catches a
# corrupt length field before it turns into an absurd allocation.
MAX_PAYLOAD = 1 << 30

# Frame types are part of the protocol: an unknown kind fails at encode time
# on the sender, and a type/kind disagreement fails at decode time on the
# receiver (it means the stream is torn or the peer speaks another schema).
FRAME_TYPES: dict[str, int] = {
    # worker ingest transport (same kinds the process backend uses)
    "hello": 1,
    "ready": 2,
    "item": 3,
    "publish": 4,
    "metrics": 5,
    "checkpoint": 6,
    "checkpointed": 7,
    "stop": 8,
    "stopped": 9,
    "failed": 10,
    # query front-end
    "info_req": 20,
    "info": 21,
    "query": 22,
    "result": 23,
    "reject": 24,
    "error": 25,
    # liveness
    "ping": 30,
    "pong": 31,
}
_KIND_BY_TYPE = {v: k for k, v in FRAME_TYPES.items()}


class WireError(ValueError):
    """A frame violated the protocol (bad magic/version/type/length)."""


def encode_message(msg: tuple) -> bytes:
    """Frame a ``(kind, ...)`` message tuple as header + pickled payload."""
    if not isinstance(msg, tuple) or not msg or not isinstance(msg[0], str):
        raise WireError(f"wire messages are ('kind', ...) tuples, got {type(msg).__name__}")
    ftype = FRAME_TYPES.get(msg[0])
    if ftype is None:
        raise WireError(f"unknown wire message kind {msg[0]!r}; known kinds: {sorted(FRAME_TYPES)}")
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload of {len(payload)} bytes exceeds MAX_PAYLOAD={MAX_PAYLOAD}")
    return _HEADER.pack(MAGIC, WIRE_VERSION, ftype, len(payload)) + payload


def decode_header(header: bytes) -> tuple[str, int]:
    """Validate a frame header; returns ``(kind, payload_length)``."""
    if len(header) != HEADER_SIZE:
        raise WireError(f"short frame header: got {len(header)} bytes, need {HEADER_SIZE}")
    magic, version, ftype, length = _HEADER.unpack(header)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (expected {MAGIC!r}): not a kmatrix wire stream")
    if version != WIRE_VERSION:
        raise WireError(
            f"wire schema version mismatch: peer speaks v{version}, this build speaks v{WIRE_VERSION}")
    kind = _KIND_BY_TYPE.get(ftype)
    if kind is None:
        raise WireError(f"unknown frame type {ftype}")
    if length > MAX_PAYLOAD:
        raise WireError(f"frame length {length} exceeds MAX_PAYLOAD={MAX_PAYLOAD}")
    return kind, length


def decode_message(buf: bytes) -> tuple:
    """Inverse of :func:`encode_message`; loud on any header/body mismatch."""
    kind, length = decode_header(buf[:HEADER_SIZE])
    body = buf[HEADER_SIZE:]
    if len(body) != length:
        raise WireError(
            f"truncated frame: header promises {length} payload bytes, got {len(body)}")
    try:
        msg = pickle.loads(body)
    except Exception as exc:  # noqa: BLE001 — surface as protocol error
        raise WireError(f"undecodable {kind!r} payload: {exc!r}") from exc
    if not isinstance(msg, tuple) or not msg or msg[0] != kind:
        got = msg[0] if isinstance(msg, tuple) and msg else type(msg).__name__
        raise WireError(f"frame type says {kind!r} but payload says {got!r}")
    return msg


# ---------------------------------------------------------------------------
# socket transport


def send_message(sock: socket.socket, msg: tuple, *,
                 deadline_s: float = 120.0) -> None:
    """Frame and send ``msg``; raises ``TimeoutError`` past ``deadline_s``."""
    sock.settimeout(deadline_s)
    try:
        sock.sendall(encode_message(msg))
    except socket.timeout as exc:
        raise TimeoutError(
            f"send of {msg[0]!r} frame did not complete within {deadline_s}s") from exc


def _recv_exact(sock: socket.socket, n: int, deadline: float,
                what: str) -> bytes:
    chunks: list[bytes] = []
    got = 0
    while got < n:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(
                f"frame deadline expired mid-{what}: got {got}/{n} bytes")
        sock.settimeout(min(remaining, 1.0))
        try:
            chunk = sock.recv(n - got)
        except socket.timeout:
            continue
        if not chunk:
            raise ConnectionError(
                f"connection closed mid-{what} (short read: {got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_message(sock: socket.socket, *, poll_s: float = 0.2,
                 frame_deadline_s: float = 120.0) -> tuple | None:
    """Receive one frame.

    Returns ``None`` if no frame *starts* within ``poll_s`` (idle peer — the
    caller's poll loop decides what idleness means).  Once the first byte
    arrives the whole frame must land within ``frame_deadline_s``.  A closed
    peer raises ``ConnectionError``; protocol violations raise
    :class:`WireError`.
    """
    sock.settimeout(poll_s)
    try:
        first = sock.recv(1)
    except socket.timeout:
        return None
    if not first:
        raise ConnectionError("connection closed by peer")
    deadline = time.monotonic() + frame_deadline_s
    header = first + _recv_exact(sock, HEADER_SIZE - 1, deadline, "header")
    kind, length = decode_header(header)
    body = _recv_exact(sock, length, deadline, f"{kind!r} payload")
    return decode_message(header + body)


def connect_with_retry(address: tuple[str, int], *, deadline_s: float,
                       stop: "object | None" = None) -> socket.socket:
    """Dial ``address``, retrying refusals until ``deadline_s`` elapses.

    ``stop`` is an optional ``threading.Event``-like object; setting it
    aborts the dial loop (used so ``Runtime.stop()`` can cancel a connect
    that would otherwise spin out its full deadline).
    """
    deadline = time.monotonic() + deadline_s
    last: Exception | None = None
    while time.monotonic() < deadline:
        if stop is not None and stop.is_set():
            raise ConnectionAbortedError(f"dial of {address} cancelled by stop")
        try:
            sock = socket.create_connection(address, timeout=min(2.0, deadline_s))
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError as exc:
            last = exc
            time.sleep(0.05)
    raise ConnectionError(
        f"could not connect to {address} within {deadline_s}s: {last!r}")


def parse_hostport(spec: str) -> tuple[str, int]:
    """``"host:port"`` → ``(host, port)`` with a loud error on junk."""
    host, sep, port = spec.rpartition(":")
    if not sep or not host or not port.isdigit():
        raise ValueError(f"expected HOST:PORT, got {spec!r}")
    return host, int(port)
