"""Versioned length-prefixed wire protocol (DESIGN.md §Net).

One codec, two transports.  Every message that crosses a worker boundary —
whether over the process backend's multiprocessing pipe or a TCP socket —
is framed as::

    MAGIC(4) | WIRE_VERSION(u16) | FRAME_TYPE(u16) | LENGTH(u32) | PAYLOAD

with the payload a pickled message tuple ``(kind, ...)`` for CONTROL
frames, and — since v3 — a raw columnar layout for the two hot-path
payloads: ``item_cols`` frames carry an edge batch as a fixed struct
header plus the src/dst/weight column buffers verbatim (encoded by buffer
concatenation, decoded with ``np.frombuffer`` views — no pickle anywhere
on the item path), and delta publishes ride a compact per-leaf
sparse/dense encoding (:func:`encode_leaves`).  The header exists so a
version skew or a torn stream fails as a loud :class:`WireError` naming the
mismatch instead of a pickle-level crash deep inside a worker.

Deadline discipline (satellite: no hangs by construction): the socket
receive path separates *idle* from *mid-frame* waiting.  ``recv_message``
polls up to ``poll_s`` for the first byte and returns ``None`` if the peer
is merely quiet, but once a frame has started the remainder must arrive
within ``frame_deadline_s`` or the read raises — a peer that wedges halfway
through a frame can never hang its reader.

Payload trust: frames are decoded with a RESTRICTED unpickler.  Only the
three ``# wire-type`` marked repro dataclasses (``_SAFE_REPRO_CLASSES``),
numpy array/scalar reconstruction, and a short builtins/collections
allowlist may appear as pickle globals; anything else
(``os.system``, ``builtins.eval``, ...) raises :class:`WireError` instead
of executing — a crafted frame from a hostile peer cannot become remote
code execution.  On top of that, listeners refuse to bind non-loopback
addresses unless a shared auth token is configured
(:func:`check_bind_allowed`); with a token set, every connection must open
with an ``auth`` frame carrying it before any other traffic is honoured.
"""
from __future__ import annotations

import hmac
import io
import os
import pickle
import socket
import struct
import time

import numpy as np

from repro.obs.hub import get_hub

MAGIC = b"KMTX"
# Version history (DESIGN.md §Observability: bump on ANY schema change a
# v(N-1) peer could misread — new frame types, new positional fields):
#   1  PR 6 baseline
#   2  `item` frames append trace_id; metrics_req scrape frame; publish/
#      metrics/stopped payloads may carry an "obs" telemetry member
#   3  columnar `item_cols` frames (raw src/dst/weight buffers, no pickle
#      on the item path); `resync` control frame; publish payloads become
#      dicts carrying a "mode" (full | delta) and, for deltas, sparse-
#      encoded leaves + a base_epoch.  v2 frames still DECODE during the
#      bump window (old `item`/`publish` shapes parse via *rest / dict
#      defaults) but this build always SENDS v3.
WIRE_VERSION = 3
# Decode-side compat window: a v2 peer's frames carry no field this build
# misreads (v3 only ADDS types and payload members), so both versions are
# accepted on receive.  Anything else is loud skew.
COMPAT_VERSIONS = frozenset({2, WIRE_VERSION})

_HEADER = struct.Struct(">4sHHI")
HEADER_SIZE = _HEADER.size

# A 256 KB sketch budget times a handful of leaves plus pickling overhead is
# well under a megabyte; 1 GiB is a generous ceiling that still catches a
# corrupt length field before it turns into an absurd allocation.
MAX_PAYLOAD = 1 << 30

# Frame types are part of the protocol: an unknown kind fails at encode time
# on the sender, and a type/kind disagreement fails at decode time on the
# receiver (it means the stream is torn or the peer speaks another schema).
FRAME_TYPES: dict[str, int] = {
    # worker ingest transport (same kinds the process backend uses)
    "hello": 1,
    "ready": 2,
    "item": 3,
    "publish": 4,
    "metrics": 5,
    "checkpoint": 6,
    "checkpointed": 7,
    "stop": 8,
    "stopped": 9,
    "failed": 10,
    # telemetry scrape: reply is a "metrics" frame carrying the hub's
    # Prometheus text + merged state (served by BOTH the ingest worker
    # host and the query front-end; requires auth when a token is set)
    "metrics_req": 11,
    # v3 hot path: columnar edge batch (raw buffers, decodes to the same
    # ("item", ...) tuple) and the parent->worker full-resync request
    # (next publish must ship full leaves, not a delta)
    "item_cols": 12,
    "resync": 13,
    # query front-end
    "info_req": 20,
    "info": 21,
    "query": 22,
    "result": 23,
    "reject": 24,
    "error": 25,
    # liveness
    "ping": 30,
    "pong": 31,
    # connection auth (first frame when a shared token is configured)
    "auth": 40,
}
_KIND_BY_TYPE = {v: k for k, v in FRAME_TYPES.items()}


class WireError(ValueError):
    """A frame violated the protocol (bad magic/version/type/length)."""


# ---------------------------------------------------------------------------
# restricted payload decoding
#
# pickle.loads on bytes from a TCP peer is remote code execution by design
# (any global reachable by name can be called during load).  Wire payloads
# only ever carry our own dataclasses plus numpy leaves and plain
# containers, so the unpickler allowlists exactly that surface and treats
# every other global as a torn/hostile stream.

_SAFE_BUILTINS = frozenset({
    "complex", "bytearray", "set", "frozenset", "range", "slice"})
_SAFE_COLLECTIONS = frozenset({"deque", "OrderedDict"})
# numpy's own pickle machinery (1.x uses numpy.core.*, 2.x numpy._core.*)
_NUMPY_RECONSTRUCT_MODULES = frozenset({
    "numpy.core.multiarray", "numpy._core.multiarray",
    "numpy.core.numeric", "numpy._core.numeric"})
_NUMPY_RECONSTRUCT_NAMES = frozenset({
    "_reconstruct", "scalar", "_frombuffer"})
_NUMPY_TOPLEVEL_NAMES = frozenset({
    "ndarray", "dtype", "bool_", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "float16", "float32",
    "float64", "complex64", "complex128", "intc", "uintc", "intp",
    "uintp", "longlong", "ulonglong", "half", "single", "double",
    "longdouble", "csingle", "cdouble", "clongdouble", "str_", "bytes_"})

# The ONLY repro classes a wire payload may materialise.  Each class is
# marked `# wire-type` at its definition; the unpickler-allowlist rule
# (repro.analysis) fails CI when the two drift apart in either direction,
# so adding a class here without marking it — or shipping a marked class
# without listing it — is caught before a peer ever sees the frame.
_SAFE_REPRO_CLASSES: dict[str, frozenset] = {
    "repro.runtime.backend": frozenset({"_ChildSpec"}),   # hello frames
    "repro.serving.registry": frozenset({"TenantOrigin"}),  # _ChildSpec.origin
    "repro.serving.engine": frozenset({"Request"}),       # query frames
}


class _RestrictedUnpickler(pickle.Unpickler):
    def find_class(self, module: str, name: str):
        allowed = (
            (module == "builtins" and name in _SAFE_BUILTINS)
            or (module == "collections" and name in _SAFE_COLLECTIONS)
            or (module in _NUMPY_RECONSTRUCT_MODULES
                and name in _NUMPY_RECONSTRUCT_NAMES)
            or (module == "numpy" and name in _NUMPY_TOPLEVEL_NAMES)
            or (module == "numpy.dtypes" and name.endswith("DType"))
            or name in _SAFE_REPRO_CLASSES.get(module, ())
        )
        if not allowed:
            raise pickle.UnpicklingError(
                f"global {module}.{name} is not allowed in a wire payload")
        return super().find_class(module, name)


def restricted_loads(data: bytes):
    """``pickle.loads`` limited to the wire's allowlisted globals."""
    return _RestrictedUnpickler(io.BytesIO(data)).load()


# ---------------------------------------------------------------------------
# bind policy + connection auth

AUTH_TOKEN_ENV = "KMATRIX_NET_TOKEN"


def resolve_auth_token(explicit: str | None = None) -> str:
    """Explicit token, else ``$KMATRIX_NET_TOKEN``, else ``""`` (off)."""
    if explicit:
        return str(explicit)
    return os.environ.get(AUTH_TOKEN_ENV, "")


def is_loopback_host(host: str) -> bool:
    return host == "localhost" or host == "::1" or host.startswith("127.")


def check_bind_allowed(host: str, auth_token: str, what: str) -> None:
    """Refuse a non-loopback listener with no auth configured.

    The wire carries pickled payloads; even with the restricted unpickler
    an open port is an ingest/query surface for anyone who can reach it.
    Loopback binds are the default and always allowed; binding a routable
    address is an explicit opt-in that requires a shared token
    (``--auth-token`` / ``$KMATRIX_NET_TOKEN``) every peer must present in
    an ``auth`` frame before any other traffic.
    """
    if auth_token or is_loopback_host(host):
        return
    raise ValueError(
        f"{what} refuses to bind non-loopback address {host!r} without an "
        f"auth token: pass auth_token=/--auth-token or set "
        f"${AUTH_TOKEN_ENV}, or bind 127.0.0.1")


def auth_matches(expected: str, presented: object) -> bool:
    return isinstance(presented, str) and hmac.compare_digest(
        expected, presented)


# ---------------------------------------------------------------------------
# wire byte accounting (DESIGN.md §Observability)
#
# Counted at the codec, per frame kind, so pipe bytes and socket bytes land
# in the same instruments.  ``on_wire=False`` callers (the spill-file FIFO,
# replayed captures) skip accounting — those bytes never cross a transport.

def _note_bytes(sent: bool, kind: str, nbytes: int) -> None:
    hub = get_hub()
    if sent:
        hub.counter("wire_bytes_sent",
                    "bytes encoded for a transport, by frame kind",
                    kind=kind).inc(nbytes)
    else:
        hub.counter("wire_bytes_recv",
                    "bytes decoded off a transport, by frame kind",
                    kind=kind).inc(nbytes)
        if kind == "publish":
            # the receiver of publish frames is always the adopting parent,
            # so this counter reads as "snapshot publication bytes adopted"
            hub.counter("publish_bytes",
                        "snapshot publication payload bytes adopted").inc(
                            nbytes)


def encode_message(msg: tuple, *, on_wire: bool = True) -> bytes:
    """Frame a ``(kind, ...)`` message tuple as header + pickled payload."""
    if not isinstance(msg, tuple) or not msg or not isinstance(msg[0], str):
        raise WireError(f"wire messages are ('kind', ...) tuples, got {type(msg).__name__}")
    ftype = FRAME_TYPES.get(msg[0])
    if ftype is None:
        raise WireError(f"unknown wire message kind {msg[0]!r}; known kinds: {sorted(FRAME_TYPES)}")
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload of {len(payload)} bytes exceeds MAX_PAYLOAD={MAX_PAYLOAD}")
    if on_wire:
        _note_bytes(True, msg[0], HEADER_SIZE + len(payload))
    return _HEADER.pack(MAGIC, WIRE_VERSION, ftype, len(payload)) + payload


def decode_header(header: bytes) -> tuple[str, int]:
    """Validate a frame header; returns ``(kind, payload_length)``."""
    if len(header) != HEADER_SIZE:
        raise WireError(f"short frame header: got {len(header)} bytes, need {HEADER_SIZE}")
    magic, version, ftype, length = _HEADER.unpack(header)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (expected {MAGIC!r}): not a kmatrix wire stream")
    if version not in COMPAT_VERSIONS:
        raise WireError(
            f"wire schema version mismatch: peer speaks v{version}, this "
            f"build speaks v{WIRE_VERSION} "
            f"(accepts {sorted(COMPAT_VERSIONS)})")
    kind = _KIND_BY_TYPE.get(ftype)
    if kind is None:
        raise WireError(f"unknown frame type {ftype}")
    if length > MAX_PAYLOAD:
        raise WireError(f"frame length {length} exceeds MAX_PAYLOAD={MAX_PAYLOAD}")
    return kind, length


def decode_message(buf: bytes, *, on_wire: bool = True) -> tuple:
    """Inverse of :func:`encode_message`; loud on any header/body mismatch.

    ``item_cols`` frames decode through the columnar path into the exact
    ``("item", offset, src, dst, weight, n_edges, trace_id)`` tuple the
    pickled v2 ``item`` frame carried, so every downstream consumer is
    layout-agnostic.
    """
    kind, length = decode_header(buf[:HEADER_SIZE])
    body = buf[HEADER_SIZE:]
    if len(body) != length:
        raise WireError(
            f"truncated frame: header promises {length} payload bytes, got {len(body)}")
    if on_wire:
        _note_bytes(False, kind, len(buf))
    if kind == "item_cols":
        return _decode_item_cols(body)
    try:
        msg = restricted_loads(body)
    except Exception as exc:  # noqa: BLE001 — surface as protocol error
        raise WireError(f"undecodable {kind!r} payload: {exc!r}") from exc
    if not isinstance(msg, tuple) or not msg or msg[0] != kind:
        got = msg[0] if isinstance(msg, tuple) and msg else type(msg).__name__
        raise WireError(f"frame type says {kind!r} but payload says {got!r}")
    return msg


# ---------------------------------------------------------------------------
# v3 columnar edge frames: the item hot path without pickle
#
# Payload layout (big-endian), validated field by field on decode:
#
#   offset(i64) n_edges(i64) | n_src(u32) n_dst(u32) n_weight(u32)
#   | dtype_src(8s) dtype_dst(8s) dtype_weight(8s) | trace_len(u16)
#   | trace_id utf-8 | src bytes | dst bytes | weight bytes
#
# Encode is buffer concatenation (one copy of each column into the output
# frame); decode is three ``np.frombuffer`` views over the received body —
# read-only, zero-copy.  Every length/dtype disagreement is a WireError.

_ITEM_COLS = struct.Struct(">qqIII8s8s8sH")

# dtypes a column may legally carry: fixed-width integer/float scalars.
# Anything else (object, structured, zero-itemsize) is a hostile or torn
# frame — np.frombuffer on attacker-chosen dtypes is not a surface we keep.
_COL_KINDS = frozenset("iuf")


def _col_dtype(tag: bytes, what: str) -> np.dtype:
    try:
        dt = np.dtype(tag.rstrip(b"\x00").decode("ascii"))
    except (TypeError, ValueError, UnicodeDecodeError) as exc:
        raise WireError(
            f"columnar item frame carries undecodable {what} dtype "
            f"{tag!r}: {exc!r}") from exc
    if dt.kind not in _COL_KINDS or not 1 <= dt.itemsize <= 8:
        raise WireError(
            f"columnar item frame carries disallowed {what} dtype {dt.str!r}"
            " (fixed-width int/float scalars only)")
    return dt


def encode_item_frame(item, *, on_wire: bool = True) -> bytes:  # hot-path
    """Frame one ``QueueItem``-shaped batch as a v3 columnar frame.

    ``item`` is duck-typed (``offset / src / dst / weight / n_edges /
    trace_id``) so both the runtime's queue items and ad-hoc tuples frame
    identically.  Columns are shipped in their native dtype.
    """
    cols = []
    for what in ("src", "dst", "weight"):
        a = np.ascontiguousarray(getattr(item, what))
        if a.ndim != 1:
            raise WireError(
                f"columnar item frame needs 1-D columns; {what} has shape "
                f"{a.shape}")
        if a.dtype.kind not in _COL_KINDS or not 1 <= a.dtype.itemsize <= 8:
            raise WireError(
                f"column {what} has unframeable dtype {a.dtype.str!r}")
        cols.append(a)
    src, dst, weight = cols
    trace = str(getattr(item, "trace_id", "") or "").encode("utf-8")
    if len(trace) > 0xFFFF:
        raise WireError(f"trace_id of {len(trace)} bytes exceeds 65535")
    length = (_ITEM_COLS.size + len(trace)
              + src.nbytes + dst.nbytes + weight.nbytes)
    if length > MAX_PAYLOAD:
        raise WireError(
            f"columnar payload of {length} bytes exceeds "
            f"MAX_PAYLOAD={MAX_PAYLOAD}")
    frame = b"".join((
        _HEADER.pack(MAGIC, WIRE_VERSION, FRAME_TYPES["item_cols"], length),
        _ITEM_COLS.pack(int(item.offset), int(item.n_edges),
                        src.size, dst.size, weight.size,
                        src.dtype.str.encode("ascii").ljust(8, b"\x00"),
                        dst.dtype.str.encode("ascii").ljust(8, b"\x00"),
                        weight.dtype.str.encode("ascii").ljust(8, b"\x00"),
                        len(trace)),
        trace, src.data, dst.data, weight.data))
    if on_wire:
        _note_bytes(True, "item", len(frame))
    return frame


def _decode_item_cols(body: bytes) -> tuple:  # hot-path
    """Columnar payload -> the canonical ``("item", ...)`` message tuple."""
    if len(body) < _ITEM_COLS.size:
        raise WireError(
            f"truncated columnar item header: got {len(body)} bytes, need "
            f"{_ITEM_COLS.size}")
    (offset, n_edges, n_src, n_dst, n_weight,
     dt_src, dt_dst, dt_weight, trace_len) = _ITEM_COLS.unpack_from(body)
    if not (n_src == n_dst == n_weight):
        raise WireError(
            f"columnar item frame has ragged columns: src={n_src} "
            f"dst={n_dst} weight={n_weight}")
    if not 0 <= n_edges <= n_src:
        raise WireError(
            f"columnar item frame claims {n_edges} non-padding edges in "
            f"{n_src}-row columns")
    dts = _col_dtype(dt_src, "src")
    dtd = _col_dtype(dt_dst, "dst")
    dtw = _col_dtype(dt_weight, "weight")
    expect = (_ITEM_COLS.size + trace_len + n_src * dts.itemsize
              + n_dst * dtd.itemsize + n_weight * dtw.itemsize)
    if expect != len(body):
        raise WireError(
            f"columnar item frame length mismatch: header describes "
            f"{expect} payload bytes, got {len(body)}")
    pos = _ITEM_COLS.size
    try:
        trace = body[pos:pos + trace_len].decode("utf-8")
    except UnicodeDecodeError as exc:
        raise WireError(f"undecodable trace_id bytes: {exc!r}") from exc
    pos += trace_len
    src = np.frombuffer(body, dtype=dts, count=n_src, offset=pos)
    pos += n_src * dts.itemsize
    dst = np.frombuffer(body, dtype=dtd, count=n_dst, offset=pos)
    pos += n_dst * dtd.itemsize
    weight = np.frombuffer(body, dtype=dtw, count=n_weight, offset=pos)
    return ("item", int(offset), src, dst, weight, int(n_edges), trace)


# ---------------------------------------------------------------------------
# delta-publish leaf codec
#
# A publish delta is an ``empty_like`` twin of the front sketch — same DENSE
# shape — so shipping it verbatim would cost exactly a full publish.  The
# savings come from per-leaf ADAPTIVE encoding: a leaf whose nonzero cells
# are sparse ships as (flat indices, values); one that is mostly nonzero
# (or tiny) ships dense.  Reconstruction is exact (indices + verbatim
# values), so the parent-side jitted merge stays bit-identical to the
# child's own publish.  Entries are plain numpy-only tuples, so they pass
# the restricted unpickler inside the publish control frame unchanged.

def encode_leaves(leaves: list) -> list:
    """Per-leaf adaptive sparse/dense encoding of a delta pytree's leaves."""
    out = []
    for leaf in leaves:
        a = np.asarray(leaf)
        if a.ndim == 0 or a.size == 0 or a.size >= (1 << 32):
            out.append(("dense", a))
            continue
        flat = a.ravel()
        idx = np.flatnonzero(flat)
        # 4 index bytes + one value per nonzero vs the dense leaf
        if idx.size * (4 + a.dtype.itemsize) < a.nbytes:
            out.append(("sparse", a.shape, a.dtype.str,
                        idx.astype(np.uint32), np.ascontiguousarray(flat[idx])))
        else:
            out.append(("dense", a))
    return out


def decode_leaves(entries: list) -> list:
    """Inverse of :func:`encode_leaves`; loud on malformed entries."""
    leaves = []
    for e in entries:
        tag = e[0] if isinstance(e, tuple) and e else None
        if tag == "dense":
            leaves.append(np.asarray(e[1]))
        elif tag == "sparse":
            _, shape, dtstr, idx, vals = e
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            if idx.size != vals.size or (idx.size and int(idx.max()) >= size):
                raise WireError(
                    f"sparse leaf entry indices do not fit shape {shape}")
            flat = np.zeros(size, dtype=np.dtype(dtstr))
            flat[idx] = vals
            leaves.append(flat.reshape(shape))
        else:
            raise WireError(f"unknown leaf encoding {tag!r}")
    return leaves


# ---------------------------------------------------------------------------
# socket transport


def send_message(sock: socket.socket, msg: tuple, *,
                 deadline_s: float = 120.0) -> None:
    """Frame and send ``msg``; raises ``TimeoutError`` past ``deadline_s``."""
    sock.settimeout(deadline_s)
    try:
        sock.sendall(encode_message(msg))
    except socket.timeout as exc:
        raise TimeoutError(
            f"send of {msg[0]!r} frame did not complete within {deadline_s}s") from exc


def send_frame(sock: socket.socket, frame: bytes, *,  # hot-path
               deadline_s: float = 120.0) -> None:
    """Send an already-encoded frame (e.g. :func:`encode_item_frame`)."""
    sock.settimeout(deadline_s)
    try:
        sock.sendall(frame)
    except socket.timeout as exc:
        raise TimeoutError(
            f"send of a {len(frame)}-byte frame did not complete within "
            f"{deadline_s}s") from exc


def _recv_exact(sock: socket.socket, n: int, deadline: float,
                what: str) -> bytes:
    chunks: list[bytes] = []
    got = 0
    while got < n:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(
                f"frame deadline expired mid-{what}: got {got}/{n} bytes")
        sock.settimeout(min(remaining, 1.0))
        try:
            chunk = sock.recv(n - got)
        except socket.timeout:
            continue
        if not chunk:
            raise ConnectionError(
                f"connection closed mid-{what} (short read: {got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_message(sock: socket.socket, *, poll_s: float = 0.2,
                 frame_deadline_s: float = 120.0) -> tuple | None:
    """Receive one frame.

    Returns ``None`` if no frame *starts* within ``poll_s`` (idle peer — the
    caller's poll loop decides what idleness means).  Once the first byte
    arrives the whole frame must land within ``frame_deadline_s``.  A closed
    peer raises ``ConnectionError``; protocol violations raise
    :class:`WireError`.
    """
    sock.settimeout(poll_s)
    try:
        first = sock.recv(1)
    except socket.timeout:
        return None
    if not first:
        raise ConnectionError("connection closed by peer")
    deadline = time.monotonic() + frame_deadline_s
    header = first + _recv_exact(sock, HEADER_SIZE - 1, deadline, "header")
    kind, length = decode_header(header)
    body = _recv_exact(sock, length, deadline, f"{kind!r} payload")
    return decode_message(header + body)


def connect_with_retry(address: tuple[str, int], *, deadline_s: float,
                       stop: "object | None" = None) -> socket.socket:
    """Dial ``address``, retrying refusals until ``deadline_s`` elapses.

    ``stop`` is an optional ``threading.Event``-like object; setting it
    aborts the dial loop (used so ``Runtime.stop()`` can cancel a connect
    that would otherwise spin out its full deadline).
    """
    deadline = time.monotonic() + deadline_s
    last: Exception | None = None
    while time.monotonic() < deadline:
        if stop is not None and stop.is_set():
            raise ConnectionAbortedError(f"dial of {address} cancelled by stop")
        try:
            sock = socket.create_connection(address, timeout=min(2.0, deadline_s))
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError as exc:
            last = exc
            time.sleep(0.05)
    raise ConnectionError(
        f"could not connect to {address} within {deadline_s}s: {last!r}")


def parse_hostport(spec: str) -> tuple[str, int]:
    """``"host:port"`` → ``(host, port)`` with a loud error on junk."""
    host, sep, port = spec.rpartition(":")
    if not sep or not host or not port.isdigit():
        raise ValueError(f"expected HOST:PORT, got {spec!r}")
    return host, int(port)
