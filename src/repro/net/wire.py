"""Versioned length-prefixed wire protocol (DESIGN.md §Net).

One codec, two transports.  Every message that crosses a worker boundary —
whether over the process backend's multiprocessing pipe or a TCP socket —
is framed as::

    MAGIC(4) | WIRE_VERSION(u16) | FRAME_TYPE(u16) | LENGTH(u32) | PAYLOAD

with the payload a pickled message tuple ``(kind, ...)`` using exactly the
serialization the process backend has always shipped (numpy leaves for
``QueueItem`` batches and snapshot publications).  The header exists so a
version skew or a torn stream fails as a loud :class:`WireError` naming the
mismatch instead of a pickle-level crash deep inside a worker.

Deadline discipline (satellite: no hangs by construction): the socket
receive path separates *idle* from *mid-frame* waiting.  ``recv_message``
polls up to ``poll_s`` for the first byte and returns ``None`` if the peer
is merely quiet, but once a frame has started the remainder must arrive
within ``frame_deadline_s`` or the read raises — a peer that wedges halfway
through a frame can never hang its reader.

Payload trust: frames are decoded with a RESTRICTED unpickler.  Only
``repro.*`` dataclasses, numpy array/scalar reconstruction, and a short
builtins/collections allowlist may appear as pickle globals; anything else
(``os.system``, ``builtins.eval``, ...) raises :class:`WireError` instead
of executing — a crafted frame from a hostile peer cannot become remote
code execution.  On top of that, listeners refuse to bind non-loopback
addresses unless a shared auth token is configured
(:func:`check_bind_allowed`); with a token set, every connection must open
with an ``auth`` frame carrying it before any other traffic is honoured.
"""
from __future__ import annotations

import hmac
import io
import os
import pickle
import socket
import struct
import time

MAGIC = b"KMTX"
# Version history (DESIGN.md §Observability: bump on ANY schema change a
# v(N-1) peer could misread — new frame types, new positional fields):
#   1  PR 6 baseline
#   2  `item` frames append trace_id; metrics_req scrape frame; publish/
#      metrics/stopped payloads may carry an "obs" telemetry member
WIRE_VERSION = 2

_HEADER = struct.Struct(">4sHHI")
HEADER_SIZE = _HEADER.size

# A 256 KB sketch budget times a handful of leaves plus pickling overhead is
# well under a megabyte; 1 GiB is a generous ceiling that still catches a
# corrupt length field before it turns into an absurd allocation.
MAX_PAYLOAD = 1 << 30

# Frame types are part of the protocol: an unknown kind fails at encode time
# on the sender, and a type/kind disagreement fails at decode time on the
# receiver (it means the stream is torn or the peer speaks another schema).
FRAME_TYPES: dict[str, int] = {
    # worker ingest transport (same kinds the process backend uses)
    "hello": 1,
    "ready": 2,
    "item": 3,
    "publish": 4,
    "metrics": 5,
    "checkpoint": 6,
    "checkpointed": 7,
    "stop": 8,
    "stopped": 9,
    "failed": 10,
    # telemetry scrape: reply is a "metrics" frame carrying the hub's
    # Prometheus text + merged state (served by BOTH the ingest worker
    # host and the query front-end; requires auth when a token is set)
    "metrics_req": 11,
    # query front-end
    "info_req": 20,
    "info": 21,
    "query": 22,
    "result": 23,
    "reject": 24,
    "error": 25,
    # liveness
    "ping": 30,
    "pong": 31,
    # connection auth (first frame when a shared token is configured)
    "auth": 40,
}
_KIND_BY_TYPE = {v: k for k, v in FRAME_TYPES.items()}


class WireError(ValueError):
    """A frame violated the protocol (bad magic/version/type/length)."""


# ---------------------------------------------------------------------------
# restricted payload decoding
#
# pickle.loads on bytes from a TCP peer is remote code execution by design
# (any global reachable by name can be called during load).  Wire payloads
# only ever carry our own dataclasses plus numpy leaves and plain
# containers, so the unpickler allowlists exactly that surface and treats
# every other global as a torn/hostile stream.

_SAFE_BUILTINS = frozenset({
    "complex", "bytearray", "set", "frozenset", "range", "slice"})
_SAFE_COLLECTIONS = frozenset({"deque", "OrderedDict"})
# numpy's own pickle machinery (1.x uses numpy.core.*, 2.x numpy._core.*)
_NUMPY_RECONSTRUCT_MODULES = frozenset({
    "numpy.core.multiarray", "numpy._core.multiarray",
    "numpy.core.numeric", "numpy._core.numeric"})
_NUMPY_RECONSTRUCT_NAMES = frozenset({
    "_reconstruct", "scalar", "_frombuffer"})
_NUMPY_TOPLEVEL_NAMES = frozenset({
    "ndarray", "dtype", "bool_", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "float16", "float32",
    "float64", "complex64", "complex128", "intc", "uintc", "intp",
    "uintp", "longlong", "ulonglong", "half", "single", "double",
    "longdouble", "csingle", "cdouble", "clongdouble", "str_", "bytes_"})


class _RestrictedUnpickler(pickle.Unpickler):
    def find_class(self, module: str, name: str):
        allowed = (
            (module == "builtins" and name in _SAFE_BUILTINS)
            or (module == "collections" and name in _SAFE_COLLECTIONS)
            or (module in _NUMPY_RECONSTRUCT_MODULES
                and name in _NUMPY_RECONSTRUCT_NAMES)
            or (module == "numpy" and name in _NUMPY_TOPLEVEL_NAMES)
            or (module == "numpy.dtypes" and name.endswith("DType"))
            or module == "repro" or module.startswith("repro.")
        )
        if not allowed:
            raise pickle.UnpicklingError(
                f"global {module}.{name} is not allowed in a wire payload")
        return super().find_class(module, name)


def restricted_loads(data: bytes):
    """``pickle.loads`` limited to the wire's allowlisted globals."""
    return _RestrictedUnpickler(io.BytesIO(data)).load()


# ---------------------------------------------------------------------------
# bind policy + connection auth

AUTH_TOKEN_ENV = "KMATRIX_NET_TOKEN"


def resolve_auth_token(explicit: str | None = None) -> str:
    """Explicit token, else ``$KMATRIX_NET_TOKEN``, else ``""`` (off)."""
    if explicit:
        return str(explicit)
    return os.environ.get(AUTH_TOKEN_ENV, "")


def is_loopback_host(host: str) -> bool:
    return host == "localhost" or host == "::1" or host.startswith("127.")


def check_bind_allowed(host: str, auth_token: str, what: str) -> None:
    """Refuse a non-loopback listener with no auth configured.

    The wire carries pickled payloads; even with the restricted unpickler
    an open port is an ingest/query surface for anyone who can reach it.
    Loopback binds are the default and always allowed; binding a routable
    address is an explicit opt-in that requires a shared token
    (``--auth-token`` / ``$KMATRIX_NET_TOKEN``) every peer must present in
    an ``auth`` frame before any other traffic.
    """
    if auth_token or is_loopback_host(host):
        return
    raise ValueError(
        f"{what} refuses to bind non-loopback address {host!r} without an "
        f"auth token: pass auth_token=/--auth-token or set "
        f"${AUTH_TOKEN_ENV}, or bind 127.0.0.1")


def auth_matches(expected: str, presented: object) -> bool:
    return isinstance(presented, str) and hmac.compare_digest(
        expected, presented)


def encode_message(msg: tuple) -> bytes:
    """Frame a ``(kind, ...)`` message tuple as header + pickled payload."""
    if not isinstance(msg, tuple) or not msg or not isinstance(msg[0], str):
        raise WireError(f"wire messages are ('kind', ...) tuples, got {type(msg).__name__}")
    ftype = FRAME_TYPES.get(msg[0])
    if ftype is None:
        raise WireError(f"unknown wire message kind {msg[0]!r}; known kinds: {sorted(FRAME_TYPES)}")
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload of {len(payload)} bytes exceeds MAX_PAYLOAD={MAX_PAYLOAD}")
    return _HEADER.pack(MAGIC, WIRE_VERSION, ftype, len(payload)) + payload


def decode_header(header: bytes) -> tuple[str, int]:
    """Validate a frame header; returns ``(kind, payload_length)``."""
    if len(header) != HEADER_SIZE:
        raise WireError(f"short frame header: got {len(header)} bytes, need {HEADER_SIZE}")
    magic, version, ftype, length = _HEADER.unpack(header)
    if magic != MAGIC:
        raise WireError(f"bad magic {magic!r} (expected {MAGIC!r}): not a kmatrix wire stream")
    if version != WIRE_VERSION:
        raise WireError(
            f"wire schema version mismatch: peer speaks v{version}, this build speaks v{WIRE_VERSION}")
    kind = _KIND_BY_TYPE.get(ftype)
    if kind is None:
        raise WireError(f"unknown frame type {ftype}")
    if length > MAX_PAYLOAD:
        raise WireError(f"frame length {length} exceeds MAX_PAYLOAD={MAX_PAYLOAD}")
    return kind, length


def decode_message(buf: bytes) -> tuple:
    """Inverse of :func:`encode_message`; loud on any header/body mismatch."""
    kind, length = decode_header(buf[:HEADER_SIZE])
    body = buf[HEADER_SIZE:]
    if len(body) != length:
        raise WireError(
            f"truncated frame: header promises {length} payload bytes, got {len(body)}")
    try:
        msg = restricted_loads(body)
    except Exception as exc:  # noqa: BLE001 — surface as protocol error
        raise WireError(f"undecodable {kind!r} payload: {exc!r}") from exc
    if not isinstance(msg, tuple) or not msg or msg[0] != kind:
        got = msg[0] if isinstance(msg, tuple) and msg else type(msg).__name__
        raise WireError(f"frame type says {kind!r} but payload says {got!r}")
    return msg


# ---------------------------------------------------------------------------
# socket transport


def send_message(sock: socket.socket, msg: tuple, *,
                 deadline_s: float = 120.0) -> None:
    """Frame and send ``msg``; raises ``TimeoutError`` past ``deadline_s``."""
    sock.settimeout(deadline_s)
    try:
        sock.sendall(encode_message(msg))
    except socket.timeout as exc:
        raise TimeoutError(
            f"send of {msg[0]!r} frame did not complete within {deadline_s}s") from exc


def _recv_exact(sock: socket.socket, n: int, deadline: float,
                what: str) -> bytes:
    chunks: list[bytes] = []
    got = 0
    while got < n:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(
                f"frame deadline expired mid-{what}: got {got}/{n} bytes")
        sock.settimeout(min(remaining, 1.0))
        try:
            chunk = sock.recv(n - got)
        except socket.timeout:
            continue
        if not chunk:
            raise ConnectionError(
                f"connection closed mid-{what} (short read: {got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_message(sock: socket.socket, *, poll_s: float = 0.2,
                 frame_deadline_s: float = 120.0) -> tuple | None:
    """Receive one frame.

    Returns ``None`` if no frame *starts* within ``poll_s`` (idle peer — the
    caller's poll loop decides what idleness means).  Once the first byte
    arrives the whole frame must land within ``frame_deadline_s``.  A closed
    peer raises ``ConnectionError``; protocol violations raise
    :class:`WireError`.
    """
    sock.settimeout(poll_s)
    try:
        first = sock.recv(1)
    except socket.timeout:
        return None
    if not first:
        raise ConnectionError("connection closed by peer")
    deadline = time.monotonic() + frame_deadline_s
    header = first + _recv_exact(sock, HEADER_SIZE - 1, deadline, "header")
    kind, length = decode_header(header)
    body = _recv_exact(sock, length, deadline, f"{kind!r} payload")
    return decode_message(header + body)


def connect_with_retry(address: tuple[str, int], *, deadline_s: float,
                       stop: "object | None" = None) -> socket.socket:
    """Dial ``address``, retrying refusals until ``deadline_s`` elapses.

    ``stop`` is an optional ``threading.Event``-like object; setting it
    aborts the dial loop (used so ``Runtime.stop()`` can cancel a connect
    that would otherwise spin out its full deadline).
    """
    deadline = time.monotonic() + deadline_s
    last: Exception | None = None
    while time.monotonic() < deadline:
        if stop is not None and stop.is_set():
            raise ConnectionAbortedError(f"dial of {address} cancelled by stop")
        try:
            sock = socket.create_connection(address, timeout=min(2.0, deadline_s))
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError as exc:
            last = exc
            time.sleep(0.05)
    raise ConnectionError(
        f"could not connect to {address} within {deadline_s}s: {last!r}")


def parse_hostport(spec: str) -> tuple[str, int]:
    """``"host:port"`` → ``(host, port)`` with a loud error on junk."""
    host, sep, port = spec.rpartition(":")
    if not sep or not host or not port.isdigit():
        raise ValueError(f"expected HOST:PORT, got {spec!r}")
    return host, int(port)
