"""Worker-host side of the socket ingest transport (DESIGN.md §Net).

A worker session is the parent's ``run_ingest_worker`` loop driven over a
TCP connection instead of a multiprocessing pipe: the parent dials in (or
a self-hosted child dials back), sends a ``hello`` frame carrying the
picklable ``_ChildSpec``, and from then on the stream carries exactly the
process-backend message kinds (``item`` — shipped as v3 columnar
``item_cols`` frames, decoded without pickle — and ``resync`` in;
``ready`` / ``publish`` / ``metrics`` / ``checkpointed`` / ``stopped`` /
``failed`` out).  A parent that re-dials after losing its connection
opens a NEW session with a fresh hello built from its adopted state, so
the first publish of that session is a full-leaves resync by
construction — the server needs no cross-session memory.

``WorkerServer`` is the standing flavour (``stream_ingest --listen
HOST:PORT``): it accepts any number of parent connections, one worker
session per connection, each in its own thread — so one worker host can
hold several shards of one parent, or shards of several parents.
``_selfhost_worker_main`` is the loopback flavour the default
``SocketBackend`` uses so a single command still exercises the full TCP
path end-to-end.

Deadline discipline (no hangs by construction): the accept loop polls so
``stop()`` lands within a poll tick, a connection that never says hello is
dropped after ``hello_timeout_s``, and every in-session read/write carries
the wire layer's frame deadline.
"""
from __future__ import annotations

import signal
import socket
import threading
import time

from repro.net import wire


def serve_worker_session(conn: socket.socket, *,
                         hello_timeout_s: float = 300.0,
                         frame_deadline_s: float = 120.0,
                         auth_token: str = "") -> str:
    """Run one ingest-worker session over an established connection.

    Blocks until the parent stops the worker (returns ``"stopped"``), the
    worker fails (``"failed"``), or the transport dies.  The jax runtime
    (and the tenant) is built lazily inside ``run_ingest_worker`` from the
    spec the ``hello`` frame ships.  With ``auth_token`` set, the peer
    must present it in an ``auth`` frame before the hello is honoured
    (without one, stray ``auth`` frames are ignored — clients may always
    send their token).
    """
    from repro.runtime.backend import run_ingest_worker

    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    send_lock = threading.Lock()  # publish callback vs loop share the socket

    def recv(timeout_s: float):
        return wire.recv_message(conn, poll_s=timeout_s,
                                 frame_deadline_s=frame_deadline_s)

    def send(msg) -> None:
        with send_lock:
            wire.send_message(conn, msg, deadline_s=frame_deadline_s)

    deadline = time.monotonic() + hello_timeout_s
    authed = not auth_token
    hello = None
    scraped = False
    while hello is None:
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"no hello frame within {hello_timeout_s}s; dropping peer")
        try:
            msg = recv(0.5)
        except ConnectionError:
            if scraped:
                return "scraped"  # scrape-only peer hung up cleanly
            raise
        if msg is None:
            continue
        if msg[0] == "auth":
            if auth_token and not wire.auth_matches(
                    auth_token, msg[1] if len(msg) > 1 else None):
                raise wire.WireError("auth failed; dropping peer")
            authed = True
            continue
        if msg[0] == "metrics_req":
            # scrape surface: same auth gate as a worker session — the hub
            # exposes tenant ids and throughput, not public data
            if not authed:
                raise wire.WireError(
                    "auth token required before a metrics scrape; "
                    "dropping peer")
            send(("metrics", scrape_payload()))
            scraped = True
            continue
        hello = msg
    if not authed:
        raise wire.WireError(
            "auth token required before a worker session; dropping peer")
    if hello[0] != "hello":
        raise wire.WireError(
            f"expected a hello frame to open a worker session, got {hello[0]!r}")
    return run_ingest_worker(hello[1], recv, send)


def scrape_payload() -> dict:
    """One ``metrics`` scrape reply (see ``repro.obs.dump`` — the wire
    frame, the ``--metrics-json`` file and the dashboard poll all carry
    this exact shape)."""
    from repro.obs.dump import scrape_payload as _payload

    return _payload()


def _selfhost_worker_main(host: str, port: int, env: dict) -> None:
    """Child entry for the self-hosted (loopback) socket worker: dial the
    parent's per-worker listener and serve one session.  Spawn-safe."""
    signal.signal(signal.SIGINT, signal.SIG_IGN)  # parent orchestrates drains
    import os

    os.environ.update(env)  # before jax initializes (spec.env re-applies)
    sock = wire.connect_with_retry((host, port), deadline_s=60.0)
    try:
        serve_worker_session(sock)
    finally:
        try:
            sock.close()
        except OSError:
            pass


class WorkerServer:
    """Standing worker host: accept parent connections, one session each."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 hello_timeout_s: float = 300.0,
                 frame_deadline_s: float = 120.0,
                 auth_token: str | None = None) -> None:
        self.auth_token = wire.resolve_auth_token(auth_token)
        wire.check_bind_allowed(host, self.auth_token, "WorkerServer")
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self.hello_timeout_s = hello_timeout_s
        self.frame_deadline_s = frame_deadline_s
        self._stop = threading.Event()
        self._sessions: list[threading.Thread] = []
        self.sessions_served = 0
        self.session_results: list[str] = []
        self._lock = threading.Lock()

    def _run_session(self, conn: socket.socket, peer) -> None:
        try:
            status = serve_worker_session(
                conn, hello_timeout_s=self.hello_timeout_s,
                frame_deadline_s=self.frame_deadline_s,
                auth_token=self.auth_token)
        except (ConnectionError, TimeoutError, OSError, wire.WireError) as exc:
            # a dead/misbehaving parent ends its own session only; the
            # parent side is where it surfaces as WorkerFailure
            status = f"aborted: {exc!r}"
        finally:
            try:
                conn.close()
            except OSError:
                pass
        with self._lock:
            self.sessions_served += 1
            self.session_results.append(status)

    def serve_forever(self, *, max_sessions: int | None = None,
                      idle_timeout_s: float | None = None) -> None:
        """Accept until ``stop()``; optionally exit after ``max_sessions``
        sessions COMPLETE or after ``idle_timeout_s`` with no live session
        (both for scripted/CI runs so a lost parent can't wedge the host)."""
        self._listener.settimeout(0.25)
        idle_since = time.monotonic()
        while not self._stop.is_set():
            self._sessions = [t for t in self._sessions if t.is_alive()]
            if max_sessions is not None and not self._sessions \
                    and self.sessions_served >= max_sessions:
                break
            if self._sessions:
                idle_since = time.monotonic()
            elif idle_timeout_s is not None \
                    and time.monotonic() - idle_since > idle_timeout_s:
                break
            try:
                conn, peer = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break  # listener closed under us by stop()
            t = threading.Thread(target=self._run_session, args=(conn, peer),
                                 daemon=True,
                                 name=f"worker-session-{peer[0]}:{peer[1]}")
            self._sessions.append(t)
            t.start()
        self.close()

    def stop(self) -> None:
        self._stop.set()
        self.close()

    def close(self) -> None:
        try:
            self._listener.close()
        except OSError:
            pass
