"""repro.net — the network transport tier (DESIGN.md §Net).

Lets the two serialized runtime seams (``QueueItem``s in, epoch-stamped
snapshot publications out — see ``runtime/backend.py``) cross host
boundaries, and puts a front-end query server with admission control in
front of the batched ``QueryEngine``:

  wire           versioned length-prefixed frames (magic + schema version +
                 frame type + payload); ONE codec shared by the socket
                 transport and the process backend's pipes
  ingest_server  worker-host side: accept a parent connection, rebuild the
                 tenant from the shipped spec, run the standard
                 ``IngestWorker`` loop (``stream_ingest --listen``)
  backend        parent side: ``SocketBackend``/``SocketWorker`` — a third
                 ``ExecutionBackend`` whose workers live across a TCP
                 connection (self-hosted loopback child by default)
  query_server   front-end TCP query server: coalesces concurrent client
                 requests into the pad-to-bucket batch planner, with a
                 bounded in-flight budget (fast-reject + Retry-After hint)
                 and per-tenant token-bucket rate limiting

Heavy submodules are loaded lazily: ``repro.runtime`` imports ``net.wire``
for the shared codec, and an eager import of ``net.backend`` here would
close an import cycle back into ``repro.runtime``.
"""
from repro.net.wire import (  # noqa: F401  (re-export: the codec is light)
    MAGIC,
    WIRE_VERSION,
    WireError,
    decode_message,
    encode_message,
    recv_message,
    send_message,
)

_LAZY = {
    "SocketBackend": "repro.net.backend",
    "SocketWorker": "repro.net.backend",
    "WorkerServer": "repro.net.ingest_server",
    "serve_worker_session": "repro.net.ingest_server",
    "QueryServer": "repro.net.query_server",
    "QueryClient": "repro.net.query_server",
    "Rejected": "repro.net.query_server",
}

__all__ = [
    "MAGIC",
    "WIRE_VERSION",
    "WireError",
    "decode_message",
    "encode_message",
    "recv_message",
    "send_message",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.net' has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
