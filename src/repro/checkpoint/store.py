"""Checkpointing: npz-leaf + JSON-treedef, atomic, with stream offsets.

Design for the 1000-node story (DESIGN.md §Fault-tolerance):
  * checkpoint = (pytree state, step metadata, stream offset) — the stream
    is seekable (batch i is a pure function of (seed, i)), so restore is
    bit-exact replay, verified by tests/test_fault_tolerance.py;
  * writes are atomic (tmp + rename) so a crash mid-save never corrupts the
    latest checkpoint; a rolling window of ``keep`` checkpoints is retained;
  * on a real cluster each host writes only its addressable shards
    (process-local npz) and restore re-shards via the mesh — here with one
    process the gather is trivial, but the layout (per-leaf arrays keyed by
    tree path) is exactly the multi-host one.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import numpy as np
import jax


def _flatten_with_paths(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, state: Any, *, extra: dict | None = None,
         keep: int = 3) -> str:
    """Atomically write checkpoint ``step``; prune old ones. Returns path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves = _flatten_with_paths(state)
        np.savez(os.path.join(tmp, "leaves.npz"), **leaves)
        meta = {
            "step": step,
            "extra": extra or {},
            "leaf_keys": sorted(leaves.keys()),
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory) if d.startswith("step_")
    )
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("step_"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def read_meta(directory: str, step: int | None = None) -> dict:
    """Metadata of checkpoint ``step`` (default: latest) without loading
    arrays — lets callers validate identity/compatibility cheaply before a
    full ``restore``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        return json.load(f)


def restore(directory: str, template: Any, step: int | None = None):
    """Restore into the structure of ``template``. Returns (state, meta)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    filled = []
    for p, leaf in paths_leaves[0]:
        key = "/".join(str(x) for x in p)
        if key not in data.files:
            # Forward compatibility: a template may carry leaves an older
            # checkpoint never wrote (e.g. the KMatrix ``overflow``
            # diagnostic added after the checkpoint was taken).  The
            # template holds the freshly-built default for exactly that
            # case, so fall back to it instead of crashing the restore —
            # and surface what was filled in the returned metadata so a
            # caller can refuse if the gap matters to it.
            filled.append(key)
            leaves.append(np.asarray(leaf))
            continue
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    state = jax.tree_util.tree_unflatten(paths_leaves[1], leaves)
    meta["filled_from_template"] = filled
    return state, meta
