from repro.common.struct import pytree_dataclass, static_field, tree_size_bytes
from repro.common.hashing import HashFamily, fastrange, hash_pair_mix

__all__ = [
    "pytree_dataclass",
    "static_field",
    "tree_size_bytes",
    "HashFamily",
    "fastrange",
    "hash_pair_mix",
]
