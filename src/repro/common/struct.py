"""Lightweight pytree dataclasses (no flax dependency).

``@pytree_dataclass`` registers a frozen dataclass as a JAX pytree whose
array-valued fields are children and whose ``static`` fields are part of the
treedef (hashable aux data). This is the substrate every sketch / model /
optimizer state in repro is built on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, TypeVar

import jax

T = TypeVar("T")

_STATIC_MARK = "__repro_static__"


def static_field(**kwargs: Any) -> Any:
    """A dataclass field stored in the treedef (must be hashable)."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata[_STATIC_MARK] = True
    return dataclasses.field(metadata=metadata, **kwargs)


def pytree_dataclass(cls: type[T]) -> type[T]:
    """Register ``cls`` (made into a frozen dataclass) as a pytree node."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = dataclasses.fields(cls)
    data_names = tuple(
        f.name for f in fields if not f.metadata.get(_STATIC_MARK, False)
    )
    static_names = tuple(
        f.name for f in fields if f.metadata.get(_STATIC_MARK, False)
    )

    def flatten_with_keys(obj):
        children = tuple(
            (jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in data_names
        )
        aux = tuple(getattr(obj, n) for n in static_names)
        return children, aux

    def flatten(obj):
        return tuple(getattr(obj, n) for n in data_names), tuple(
            getattr(obj, n) for n in static_names
        )

    def unflatten(aux, children):
        kwargs = dict(zip(data_names, children))
        kwargs.update(dict(zip(static_names, aux)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_with_keys(
        cls, flatten_with_keys, unflatten, flatten_func=flatten
    )

    def replace(self: T, **updates: Any) -> T:
        return dataclasses.replace(self, **updates)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls


def field_names(obj: Any) -> tuple[str, ...]:
    return tuple(f.name for f in dataclasses.fields(obj))


def tree_size_bytes(tree: Any) -> int:
    """Total bytes of all array leaves in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += leaf.size * leaf.dtype.itemsize
    return total


def tree_map_with_path(fn: Callable, tree: Any) -> Any:
    return jax.tree_util.tree_map_with_path(fn, tree)
