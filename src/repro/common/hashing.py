"""Pairwise-independent hash families, vectorized for JAX.

The gMatrix/kMatrix constructions require *pairwise independent* hash
functions (so reverse/heavy-hitter reasoning holds).  We use the
Dietzfelbinger multiply-shift family over 32-bit words:

    h_{a,b}(x) = ((a * x + b) mod 2^32) >> (32 - M)        (2-independent)

which is exactly 2-independent onto ``2^M`` buckets when ``a, b`` are drawn
uniformly from ``[0, 2^32)``.  For arbitrary (non power-of-two) ranges we
compose with the "fastrange" reduction ``(h * w) >> 32`` which preserves
near-uniformity without an expensive modulo.

Everything here is uint32 arithmetic (no jax_enable_x64 needed) and fully
vectorized: a batch of 2^20 edge endpoints hashes in one fused elementwise op.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.common.struct import pytree_dataclass

_U32 = jnp.uint32
_MASK32 = np.uint32(0xFFFFFFFF)


def sample_hash_params(seed: int, n_funcs: int) -> tuple[np.ndarray, np.ndarray]:
    """Draw (a, b) for ``n_funcs`` independent 2-universal hash functions.

    ``a`` is forced odd (classical multiply-shift requirement; harmless for
    the add-shift variant and strictly better avalanche behaviour).
    """
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << 32, size=n_funcs, dtype=np.uint32) | np.uint32(1)
    b = rng.integers(0, 1 << 32, size=n_funcs, dtype=np.uint32)
    return a, b


@pytree_dataclass
class HashFamily:
    """A bank of ``d`` pairwise-independent hash functions.

    Attributes:
      a, b: uint32[d] multiply-shift parameters.
    """

    a: jax.Array  # uint32[d]
    b: jax.Array  # uint32[d]

    @staticmethod
    def create(seed: int, d: int) -> "HashFamily":
        a, b = sample_hash_params(seed, d)
        return HashFamily(a=jnp.asarray(a), b=jnp.asarray(b))

    @property
    def depth(self) -> int:
        return self.a.shape[0]

    def mix(self, x: jax.Array) -> jax.Array:
        """Full-width 32-bit hash of ``x`` under every function.

        Args:
          x: int/uint array of shape ``S``.
        Returns:
          uint32 array of shape ``(d, *S)``.
        """
        x = x.astype(_U32)
        a = self.a.reshape((-1,) + (1,) * x.ndim)
        b = self.b.reshape((-1,) + (1,) * x.ndim)
        h = a * x[None] + b
        # One extra xorshift round: multiply-shift's low bits are weak and
        # fastrange consumes the *high* bits, but the xor folds low entropy up
        # for adversarial (sequential-id) key sets seen in graph streams.
        h = h ^ (h >> 16)
        h = h * np.uint32(0x7FEB352D)
        h = h ^ (h >> 15)
        return h

    def hash_into(self, x: jax.Array, w: int | jax.Array) -> jax.Array:
        """Hash ``x`` into ``[0, w)`` under every function -> int32[d, *S]."""
        return fastrange(self.mix(x), w)


def families_match(a: HashFamily, b: HashFamily) -> bool | None:
    """Whether two hash families are identical (same seeds/params).

    Returns ``None`` when either family is a tracer (inside jit the values
    are not inspectable; callers skip the check there).  Used by sketch
    ``merge`` to reject operands built with different seeds — the layouts
    can agree while the hash functions do not, which would silently corrupt
    every estimate.
    """
    xs = (a.a, a.b, b.a, b.b)
    if any(isinstance(x, jax.core.Tracer) for x in xs):
        return None
    return (
        a.a.shape == b.a.shape
        and bool(np.array_equal(np.asarray(a.a), np.asarray(b.a)))
        and bool(np.array_equal(np.asarray(a.b), np.asarray(b.b)))
    )


def fastrange(h: jax.Array, w: int | jax.Array) -> jax.Array:
    """Map uniform uint32 ``h`` to ``[0, w)`` via (h * w) >> 32.

    Implemented with a 32x32 -> high-32 multiply decomposed into 16-bit limbs
    so that it stays in uint32 (no x64 requirement).
    """
    h = h.astype(_U32)
    w_arr = jnp.asarray(w, dtype=_U32)
    h_lo = h & np.uint32(0xFFFF)
    h_hi = h >> 16
    w_lo = w_arr & np.uint32(0xFFFF)
    w_hi = w_arr >> 16
    # h * w = (h_hi*w_hi << 32) + ((h_hi*w_lo + h_lo*w_hi) << 16) + h_lo*w_lo
    mid = h_hi * w_lo + h_lo * w_hi + ((h_lo * w_lo) >> 16)
    high = h_hi * w_hi + (mid >> 16)
    return high.astype(jnp.int32)


def hash_pair_mix(x: jax.Array, y: jax.Array) -> jax.Array:
    """Combine two uint32 streams into one (for edge-keyed hashing)."""
    x = x.astype(_U32)
    y = y.astype(_U32)
    h = x * np.uint32(0x85EBCA6B) + (y ^ (y >> 13)) * np.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def np_hash_into(a: np.ndarray, b: np.ndarray, x: np.ndarray, w: int) -> np.ndarray:
    """NumPy oracle mirroring HashFamily.hash_into (used by tests + host-side
    partition routing). Shapes: a,b -> [d], x -> [*S]; returns [d, *S]."""
    x = x.astype(np.uint32)
    a = a.reshape((-1,) + (1,) * x.ndim).astype(np.uint32)
    b = b.reshape((-1,) + (1,) * x.ndim).astype(np.uint32)
    with np.errstate(over="ignore"):
        h = a * x[None] + b
        h = h ^ (h >> np.uint32(16))
        h = h * np.uint32(0x7FEB352D)
        h = h ^ (h >> np.uint32(15))
        prod = h.astype(np.uint64) * np.uint64(w)
    return (prod >> np.uint64(32)).astype(np.int32)
