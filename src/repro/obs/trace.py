"""Bounded trace-span log: IDs minted at the edges, events everywhere.

A trace ID is minted once per unit of work — an edge batch at
ingest-enqueue (``QueueItem.from_arrays``) or a query at server accept —
and rides the existing plumbing: ``QueueItem.trace_id`` through queues
and spills, a new field on the wire codec's ``item`` frames (version 2),
and span-event lists inside publish/metrics beats coming back up.

Each process keeps one bounded ring (``get_trace_log()``).  Remote
workers ``drain()`` their ring into the beats they already send; the
parent ``absorb()``s, so one batch's enqueue -> dispatch -> publish ->
adopt chain (or a query's accept -> plan -> execute -> reply chain) is
reconstructable from a single JSONL dump regardless of transport.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any

from repro.obs.hub import metrics_disabled

__all__ = ["new_trace_id", "TraceLog", "get_trace_log", "reset_trace_log"]

DEFAULT_CAPACITY = 4096


def new_trace_id() -> str:
    return os.urandom(8).hex()


class TraceLog:
    """Thread-safe bounded ring of span events."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        self._events: deque[dict] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._emitted = 0

    def emit(self, trace_id: str, span: str, event: str,
             **attrs: Any) -> None:
        if not trace_id or metrics_disabled():
            return
        rec = {"ts": time.time(), "trace": trace_id, "span": span,
               "event": event}
        if attrs:
            rec.update(attrs)
        with self._lock:
            self._events.append(rec)
            self._emitted += 1

    def absorb(self, events) -> None:
        """Fold a batch of remote events (from a drained child ring)."""
        if not events:
            return
        with self._lock:
            for rec in events:
                if isinstance(rec, dict) and rec.get("trace"):
                    self._events.append(rec)
                    self._emitted += 1

    def drain(self) -> list[dict]:
        """Remove and return everything buffered (child -> beat path)."""
        with self._lock:
            out = list(self._events)
            self._events.clear()
            return out

    def events(self, trace_id: str | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        if trace_id is None:
            return evs
        return [e for e in evs if e["trace"] == trace_id]

    def chain(self, trace_id: str) -> list[str]:
        """The ordered event names seen for one trace."""
        return [e["event"] for e in self.events(trace_id)]

    def dump_jsonl(self, path: str) -> int:
        """Append-write current events as JSONL; returns lines written."""
        evs = self.events()
        with open(path, "a") as fh:
            for rec in evs:
                fh.write(json.dumps(rec, default=str) + "\n")
        return len(evs)

    @property
    def emitted(self) -> int:
        return self._emitted

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


_GLOBAL: TraceLog | None = None
_GLOBAL_LOCK = threading.Lock()


def get_trace_log() -> TraceLog:
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = TraceLog()
        return _GLOBAL


def reset_trace_log() -> TraceLog:
    global _GLOBAL
    with _GLOBAL_LOCK:
        _GLOBAL = TraceLog()
        return _GLOBAL
