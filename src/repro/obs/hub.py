"""Mergeable metrics hub: counters, gauges, log-bucketed histograms.

Every histogram uses a *fixed, named bucket ladder* shared by all
producers, so per-worker histograms sum exactly — across threads (shared
hub), process pipes (state dicts in metrics beats), and socket frames
(same dicts through the wire codec).  No raw sample arrays cross any
boundary; percentiles are answered from bucket counts plus exact
min/max/sum side-channels.

Topology (DESIGN.md §Observability):

- each process owns one global hub (``get_hub()``); threads share it and
  label their instruments (tenant/shard/backend/query-class)
- remote workers ship ``hub.state()`` (a plain picklable dict) inside
  their existing metrics/publish beats; the parent calls
  ``hub.adopt(source, state)`` which *replaces* that source's previous
  contribution — child states are cumulative, so replace-then-sum never
  double-counts
- ``merged_state()`` / ``render_prometheus()`` fold local + adopted
  states: counters and histogram buckets add, gauges last-write-wins

``set_disabled(True)`` turns every instrument mutation into an early
return; ``benchmarks/run.py obs`` uses it for the metrics-off arm.
"""
from __future__ import annotations

import copy
import threading
from bisect import bisect_left
from typing import Any, Callable

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsHub",
    "get_hub", "reset_hub", "set_disabled", "metrics_disabled",
    "LADDERS",
]

# ---------------------------------------------------------------- ladders
# Named, immutable bucket ladders.  States reference ladders by name so a
# merge between mismatched ladders is a hard error, never a silent skew.
#   latency: 1us .. ~95s, x sqrt(2) per bucket (54 bounds)
#   size:    1 .. 2^24, x2 per bucket (25 bounds)
LADDERS: dict[str, tuple[float, ...]] = {
    "latency": tuple(1e-6 * (2.0 ** (i / 2.0)) for i in range(54)),
    "size": tuple(float(2 ** i) for i in range(25)),
}

_disabled = False


def set_disabled(flag: bool) -> None:
    """Globally disable (or re-enable) instrument mutation — the
    metrics-off arm of the overhead benchmark."""
    global _disabled
    _disabled = bool(flag)


def metrics_disabled() -> bool:
    return _disabled


def _label_key(labels: dict[str, Any]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labels: dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_val(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


# ------------------------------------------------------------ instruments
class Counter:
    """Monotonic cumulative count.  ``set`` exists for mirroring counts
    that are maintained elsewhere (e.g. queue stats dicts)."""

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if _disabled:
            return
        with self._lock:
            self.value += n

    def set(self, v: float) -> None:
        if _disabled:
            return
        self.value = float(v)


class Gauge:
    """Point-in-time value; merges last-write-wins."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict[str, str]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        if _disabled:
            return
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        if _disabled:
            return
        self.value += n


class Histogram:
    """Log-bucketed histogram over a named fixed ladder.

    ``counts`` has ``len(bounds) + 1`` slots; slot i counts samples with
    ``value <= bounds[i]`` (prometheus ``le`` semantics), the last slot
    is the +Inf overflow.  Exact ``sum``/``count``/``min``/``max`` ride
    along so means stay exact and quantiles clamp to observed extremes.
    """

    __slots__ = ("name", "labels", "ladder", "bounds", "counts",
                 "sum", "count", "min", "max", "_lock")

    def __init__(self, name: str, labels: dict[str, str],
                 ladder: str = "latency"):
        if ladder not in LADDERS:
            raise ValueError(f"unknown ladder {ladder!r}")
        self.name = name
        self.labels = labels
        self.ladder = ladder
        self.bounds = LADDERS[ladder]
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        if _disabled:
            return
        v = float(value)
        idx = bisect_left(self.bounds, v)
        with self._lock:
            self.counts[idx] += 1
            self.sum += v
            self.count += 1
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def observe_many(self, values) -> None:
        if _disabled:
            return
        for v in values:
            self.observe(v)

    def observe_n(self, value: float, n: int) -> None:
        """Record ``n`` occurrences of ``value`` in one bucket update
        (e.g. per-request weighting of a per-batch latency)."""
        if _disabled or n <= 0:
            return
        v = float(value)
        idx = bisect_left(self.bounds, v)
        with self._lock:
            self.counts[idx] += n
            self.sum += v * n
            self.count += n
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    # -- state / merge -------------------------------------------------
    def state(self) -> dict[str, Any]:
        with self._lock:
            return {"ladder": self.ladder, "counts": list(self.counts),
                    "sum": self.sum, "count": self.count,
                    "min": self.min, "max": self.max}

    def merge_state(self, st: dict[str, Any]) -> None:
        if st["ladder"] != self.ladder:
            raise ValueError(
                f"histogram ladder mismatch: {st['ladder']!r} vs "
                f"{self.ladder!r} for {self.name}")
        with self._lock:
            for i, c in enumerate(st["counts"]):
                self.counts[i] += c
            self.sum += st["sum"]
            self.count += st["count"]
            self.min = min(self.min, st["min"])
            self.max = max(self.max, st["max"])

    # -- reads ---------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Quantile by linear interpolation within the owning bucket,
        clamped to the exact observed [min, max]."""
        return quantile_from_state(self.state(), q)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def merge_hist_states(a: dict[str, Any], b: dict[str, Any]) -> dict[str, Any]:
    if a["ladder"] != b["ladder"]:
        raise ValueError("histogram ladder mismatch")
    return {"ladder": a["ladder"],
            "counts": [x + y for x, y in zip(a["counts"], b["counts"])],
            "sum": a["sum"] + b["sum"], "count": a["count"] + b["count"],
            "min": min(a["min"], b["min"]), "max": max(a["max"], b["max"])}


def quantile_from_state(st: dict[str, Any], q: float) -> float:
    count = st["count"]
    if not count:
        return 0.0
    bounds = LADDERS[st["ladder"]]
    rank = max(0.0, min(1.0, q)) * count
    seen = 0.0
    for i, c in enumerate(st["counts"]):
        if not c:
            continue
        if seen + c >= rank:
            lo = bounds[i - 1] if i > 0 else 0.0
            hi = bounds[i] if i < len(bounds) else st["max"]
            frac = (rank - seen) / c
            v = lo + (hi - lo) * max(0.0, min(1.0, frac))
            return max(st["min"], min(st["max"], v))
        seen += c
    return st["max"]


# ----------------------------------------------------------------- hub
class MetricsHub:
    """Registry of labeled instruments plus adoption of remote states."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._hists: dict[tuple, Histogram] = {}
        self._help: dict[str, str] = {}
        self._adopted: dict[str, dict] = {}
        self._collectors: list[Callable[[], None]] = []

    # -- instrument factories (get-or-create; idempotent) --------------
    def counter(self, name: str, help: str = "", **labels) -> Counter:
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._counters.get(key)
            if inst is None:
                inst = self._counters[key] = Counter(
                    name, {k: str(v) for k, v in labels.items()})
            if help:
                self._help.setdefault(name, help)
            return inst

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._gauges.get(key)
            if inst is None:
                inst = self._gauges[key] = Gauge(
                    name, {k: str(v) for k, v in labels.items()})
            if help:
                self._help.setdefault(name, help)
            return inst

    def histogram(self, name: str, help: str = "", ladder: str = "latency",
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._hists.get(key)
            if inst is None:
                inst = self._hists[key] = Histogram(
                    name, {k: str(v) for k, v in labels.items()}, ladder)
            if help:
                self._help.setdefault(name, help)
            return inst

    # -- collectors ----------------------------------------------------
    def add_collector(self, fn: Callable[[], None]) -> None:
        """Register a callback run before every state()/render — used to
        refresh gauges and adopt remote states on demand."""
        with self._lock:
            self._collectors.append(fn)

    def remove_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            try:
                self._collectors.remove(fn)
            except ValueError:
                pass

    def _run_collectors(self) -> None:
        with self._lock:
            fns = list(self._collectors)
        for fn in fns:
            try:
                fn()
            except Exception:  # a broken collector must not kill a scrape
                pass

    # -- state / adoption ---------------------------------------------
    def state(self) -> dict[str, Any]:
        """This hub's local contribution as a plain picklable dict
        (adopted sources NOT included — suitable for shipping upward)."""
        self._run_collectors()
        with self._lock:
            return {
                "counters": [[c.name, dict(c.labels), c.value]
                             for c in self._counters.values()],
                "gauges": [[g.name, dict(g.labels), g.value]
                           for g in self._gauges.values()],
                "hists": [[h.name, dict(h.labels), h.state()]
                          for h in self._hists.values()],
                "help": dict(self._help),
            }

    def adopt(self, source: str, state: dict[str, Any]) -> None:
        """Replace ``source``'s contribution with its latest cumulative
        state (children re-ship whole state each beat)."""
        if not isinstance(state, dict):
            return
        with self._lock:
            self._adopted[source] = state

    def adopted_sources(self) -> list[str]:
        with self._lock:
            return sorted(self._adopted)

    def merged_state(self) -> dict[str, Any]:
        """Local + adopted, in sorted source order (deterministic sums:
        the exact-equality tests rely on this order)."""
        merged = copy.deepcopy(self.state())
        with self._lock:
            sources = [self._adopted[s] for s in sorted(self._adopted)]
        for st in sources:
            _fold_state(merged, st)
        return merged

    def render_prometheus(self, state: dict[str, Any] | None = None) -> str:
        return render_prometheus(self.merged_state() if state is None
                                 else state)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._adopted.clear()
            self._collectors.clear()
            self._help.clear()


def _fold_state(into: dict[str, Any], st: dict[str, Any]) -> None:
    if not isinstance(st, dict):
        return
    cidx = {(row[0], _label_key(row[1])): row for row in into["counters"]}
    for name, labels, value in st.get("counters", []):
        row = cidx.get((name, _label_key(labels)))
        if row is None:
            into["counters"].append([name, dict(labels), value])
        else:
            row[2] += value
    gidx = {(row[0], _label_key(row[1])): row for row in into["gauges"]}
    for name, labels, value in st.get("gauges", []):
        row = gidx.get((name, _label_key(labels)))
        if row is None:
            into["gauges"].append([name, dict(labels), value])
        else:
            row[2] = value
    hidx = {(row[0], _label_key(row[1])): row for row in into["hists"]}
    for name, labels, hstate in st.get("hists", []):
        row = hidx.get((name, _label_key(labels)))
        if row is None:
            into["hists"].append([name, dict(labels),
                                  copy.deepcopy(hstate)])
        else:
            row[2] = merge_hist_states(row[2], hstate)
    for name, text in st.get("help", {}).items():
        into["help"].setdefault(name, text)


def render_prometheus(state: dict[str, Any]) -> str:
    """Prometheus text exposition (v0.0.4) of a (merged) state dict."""
    help_map = state.get("help", {})
    out: list[str] = []
    by_name: dict[str, list] = {}
    for name, labels, value in state.get("counters", []):
        by_name.setdefault(("counter", name), []).append((labels, value))
    for name, labels, value in state.get("gauges", []):
        by_name.setdefault(("gauge", name), []).append((labels, value))
    for (kind, name), rows in sorted(by_name.items(), key=lambda kv: kv[0][1]):
        if name in help_map:
            out.append(f"# HELP {name} {help_map[name]}")
        out.append(f"# TYPE {name} {kind}")
        for labels, value in sorted(rows, key=lambda r: _fmt_labels(r[0])):
            out.append(f"{name}{_fmt_labels(labels)} {_fmt_val(value)}")
    hists: dict[str, list] = {}
    for name, labels, hstate in state.get("hists", []):
        hists.setdefault(name, []).append((labels, hstate))
    for name in sorted(hists):
        if name in help_map:
            out.append(f"# HELP {name} {help_map[name]}")
        out.append(f"# TYPE {name} histogram")
        for labels, hs in sorted(hists[name],
                                 key=lambda r: _fmt_labels(r[0])):
            bounds = LADDERS[hs["ladder"]]
            cum = 0
            for i, c in enumerate(hs["counts"][:-1]):
                cum += c
                if not c and i and not hs["counts"][i - 1]:
                    continue  # skip runs of empty buckets (keep edges)
                le_attr = 'le="%s"' % repr(float(bounds[i]))
                out.append(f"{name}_bucket"
                           f"{_fmt_labels(labels, le_attr)} {cum}")
            cum += hs["counts"][-1]
            inf_attr = 'le="+Inf"'
            out.append(f"{name}_bucket"
                       f"{_fmt_labels(labels, inf_attr)} {cum}")
            out.append(f"{name}_sum{_fmt_labels(labels)} "
                       f"{repr(float(hs['sum']))}")
            out.append(f"{name}_count{_fmt_labels(labels)} {hs['count']}")
    return "\n".join(out) + "\n"


def hist_summary(hs: dict[str, Any]) -> dict[str, float]:
    """Compact summary of a histogram state (for JSON reports)."""
    if not hs["count"]:
        return {"count": 0}
    return {
        "count": int(hs["count"]),
        "mean": hs["sum"] / hs["count"],
        "min": hs["min"], "max": hs["max"],
        "p50": quantile_from_state(hs, 0.50),
        "p90": quantile_from_state(hs, 0.90),
        "p99": quantile_from_state(hs, 0.99),
        "p999": quantile_from_state(hs, 0.999),
    }


# ------------------------------------------------------------ global hub
_GLOBAL: MetricsHub | None = None
_GLOBAL_LOCK = threading.Lock()


def get_hub() -> MetricsHub:
    """The process-global hub.  Spawned children start with a fresh one;
    their state reaches the parent via metrics/publish beats."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = MetricsHub()
        return _GLOBAL


def reset_hub() -> MetricsHub:
    """Replace the global hub (test isolation)."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        _GLOBAL = MetricsHub()
        return _GLOBAL
