"""Live telemetry poller: render per-shard ingest/query panels in a loop.

    python -m repro.obs.dashboard --connect HOST:PORT [--auth-token T]
    python -m repro.obs.dashboard --json /path/metrics.json

``--connect`` scrapes the ``metrics`` frame that both servers expose
(``query_serve --serve`` front-ends and ``stream_ingest --listen`` worker
hosts); ``--json`` follows a ``--metrics-json`` file instead — same
payload, no socket.  Every poll the payload's Prometheus text is run
through ``parse_prometheus_text`` so a malformed exposition fails loudly;
``--once`` renders a single frame and exits non-zero on any fetch or
parse failure, which makes it double as the CI scrape assertion.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import time

# exposition sample: name, optional {labels}, value (exponents included)
_SAMPLE_RE = re.compile(
    r"([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(-?(?:[0-9.eE+-]+|[Ii]nf|[Nn]a[Nn]))")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text: str) -> dict[tuple, float]:
    """Parse exposition text into ``{(name, ((label, value), ...)): float}``.

    Deliberately strict where it matters for our own output: every
    non-comment line must be a well-formed sample and every value must
    parse as a float, so a rendering regression fails the CI scrape check
    instead of producing silently unscrapeable metrics.
    """
    samples: dict[tuple, float] = {}
    for line in text.splitlines():
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.fullmatch(line.strip())
        if m is None:
            raise ValueError(f"unparseable exposition line: {line!r}")
        name, labelstr, raw = m.groups()
        labels = []
        if labelstr:
            matched = _LABEL_RE.findall(labelstr)
            stripped = _LABEL_RE.sub("", labelstr).replace(",", "").strip()
            if stripped:
                raise ValueError(f"malformed label set: {labelstr!r}")
            labels = [(k, v.replace('\\"', '"').replace("\\\\", "\\")
                       .replace("\\n", "\n")) for k, v in matched]
        samples[(name, tuple(sorted(labels)))] = float(raw)
    return samples


def fetch_payload(args) -> dict:
    """One scrape: over TCP (``--connect``) or from a ``--metrics-json``
    file (``--json``); both carry the ``repro.obs.dump`` payload shape."""
    if args.json:
        with open(args.json) as f:
            return json.load(f)

    from repro.net import wire

    address = wire.parse_hostport(args.connect)
    sock = wire.connect_with_retry(address, deadline_s=args.timeout_s)
    try:
        token = wire.resolve_auth_token(args.auth_token or None)
        if token:
            wire.send_message(sock, ("auth", token), deadline_s=args.timeout_s)
        wire.send_message(sock, ("metrics_req",), deadline_s=args.timeout_s)
        deadline = time.monotonic() + args.timeout_s
        while True:
            reply = wire.recv_message(sock, poll_s=0.2,
                                      frame_deadline_s=args.timeout_s)
            if reply is not None:
                break
            if time.monotonic() > deadline:
                raise TimeoutError("no metrics frame within the deadline")
        if reply[0] != "metrics":
            raise wire.WireError(f"expected metrics, got {reply[0]!r}")
        return reply[1]
    finally:
        try:
            sock.close()
        except OSError:
            pass


# ------------------------------------------------------------- rendering --


def _rows(state: dict, section: str, name: str) -> list[tuple[dict, object]]:
    return [(dict(labels), value) for n, labels, value
            in state.get(section, ()) if n == name]


def _by_tenant(state: dict, section: str, name: str) -> dict[str, object]:
    return {labels.get("tenant", ""): value
            for labels, value in _rows(state, section, name)}


def _q(hstate, q: float) -> float:
    from repro.obs.hub import quantile_from_state

    return quantile_from_state(hstate, q)


def render_panels(payload: dict) -> str:
    """Per-shard ingest panel + query panel from a scrape payload."""
    state = payload.get("state", {})
    out = [f"-- scrape @ {time.strftime('%H:%M:%S', time.localtime(payload.get('ts', 0)))} --"]

    edges = _by_tenant(state, "counters", "repro_ingest_edges_total")
    eps = _by_tenant(state, "gauges", "repro_ingest_edges_per_s")
    depth = _by_tenant(state, "gauges", "repro_queue_depth")
    epoch = _by_tenant(state, "gauges", "repro_epoch")
    dropped = _by_tenant(state, "counters", "repro_queue_dropped_edges_total")
    pub_lat = {labels.get("tenant", ""): h for labels, h
               in _rows(state, "hists", "repro_publish_latency_seconds")}
    if edges:
        out.append("ingest (per shard)")
        out.append(f"  {'tenant':<40} {'edges':>10} {'edges/s':>10} "
                   f"{'queue':>6} {'epoch':>6} {'drop':>6} {'pub p99 ms':>10}")
        for tenant in sorted(edges):
            h = pub_lat.get(tenant)
            p99 = f"{_q(h, 0.99) * 1e3:.1f}" if h and h["count"] else "-"
            out.append(
                f"  {tenant:<40} {int(edges[tenant]):>10} "
                f"{eps.get(tenant, 0.0):>10.1f} "
                f"{int(depth.get(tenant, 0)):>6} "
                f"{int(epoch.get(tenant, 0)):>6} "
                f"{int(dropped.get(tenant, 0)):>6} {p99:>10}")
    else:
        out.append("ingest: no shards reporting yet")

    ledger = {name: value for name, labels, value
              in state.get("counters", ()) if name.startswith("repro_query_")}
    lat = _rows(state, "hists", "repro_query_latency_seconds")
    if ledger or lat:
        out.append("query")
        keys = ("repro_query_offered_requests_total",
                "repro_query_served_requests_total",
                "repro_query_shed_overload_total",
                "repro_query_auth_failures_total")
        out.append("  " + "  ".join(
            f"{k.removeprefix('repro_query_').removesuffix('_total')}="
            f"{int(ledger.get(k, 0))}" for k in keys))
        inflight = _rows(state, "gauges", "repro_query_inflight")
        if inflight:
            out.append(f"  inflight={int(inflight[0][1])}")
        if lat and lat[0][1]["count"]:
            h = lat[0][1]
            out.append(
                f"  latency ms: p50={_q(h, 0.5) * 1e3:.2f} "
                f"p90={_q(h, 0.9) * 1e3:.2f} p99={_q(h, 0.99) * 1e3:.2f} "
                f"p999={_q(h, 0.999) * 1e3:.2f} n={h['count']}")
    else:
        out.append("query: no front-end reporting")
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="poll a repro telemetry surface and render live panels")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--connect", metavar="HOST:PORT",
                     help="scrape the 'metrics' frame from a query_serve "
                          "--serve or stream_ingest --listen address")
    src.add_argument("--json", metavar="PATH",
                     help="follow a --metrics-json file instead of a socket")
    ap.add_argument("--auth-token", default="",
                    help="token for a remote server "
                         "(default: $KMATRIX_NET_TOKEN)")
    ap.add_argument("--interval", type=float, default=2.0)
    ap.add_argument("--timeout-s", type=float, default=15.0)
    ap.add_argument("--once", action="store_true",
                    help="one frame then exit; non-zero on fetch/parse "
                         "failure (the CI scrape assertion)")
    args = ap.parse_args(argv)

    while True:
        try:
            payload = fetch_payload(args)
            samples = parse_prometheus_text(payload.get("prometheus", ""))
        except Exception as exc:  # noqa: BLE001 — every failure mode counts
            print(f"scrape failed: {exc!r}", file=sys.stderr)
            if args.once:
                return 1
            time.sleep(args.interval)
            continue
        print(render_panels(payload))
        print(f"   ({len(samples)} exposition samples parsed)")
        sys.stdout.flush()
        if args.once:
            return 0
        time.sleep(args.interval)


if __name__ == "__main__":
    raise SystemExit(main())
