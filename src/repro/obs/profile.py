"""Opt-in kernel timing hooks (``REPRO_PROFILE=1``).

Wraps *eager* call sites around the Pallas ingest and closure kernels —
never code inside a jit trace, where wall timing is meaningless and
``block_until_ready`` would poison tracing.  When enabled, each hooked
call runs under a ``jax.profiler.TraceAnnotation`` (visible in TPU/XLA
profiles), is blocked until ready, and its wall time lands in the hub
histogram ``repro_profile_seconds{site=...}``.

Off by default: the disabled path is a single env-cached bool check.
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager

__all__ = ["profiling_enabled", "profile_call", "profile_span"]

_ENABLED: bool | None = None


def profiling_enabled() -> bool:
    global _ENABLED
    if _ENABLED is None:
        _ENABLED = os.environ.get("REPRO_PROFILE", "") == "1"
    return _ENABLED


def _reset_for_tests() -> None:
    global _ENABLED
    _ENABLED = None


def _record(site: str, dt_s: float) -> None:
    from repro.obs.hub import get_hub
    get_hub().histogram(
        "repro_profile_seconds",
        "wall time of profiled kernel call sites (REPRO_PROFILE=1)",
        site=site).observe(dt_s)


@contextmanager
def profile_span(site: str):
    """Context manager form for multi-statement regions."""
    if not profiling_enabled():
        yield
        return
    import jax
    with jax.profiler.TraceAnnotation(site):
        t0 = time.perf_counter()
        yield
    _record(site, time.perf_counter() - t0)


def profile_call(site: str, fn, *args, **kwargs):
    """Call ``fn`` and, when profiling, block on its result and record
    the wall time.  The result is returned either way."""
    if not profiling_enabled():
        return fn(*args, **kwargs)
    import jax
    with jax.profiler.TraceAnnotation(site):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass  # non-array outputs time the dispatch only
        dt = time.perf_counter() - t0
    _record(site, dt)
    return out
