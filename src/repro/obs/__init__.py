"""Unified telemetry tier (DESIGN.md §Observability).

- ``hub``: mergeable counters/gauges/log-bucketed histograms + Prometheus
  text exposition
- ``trace``: bounded span log with IDs propagated through queues, the
  wire codec, and publish adoption
- ``profile``: REPRO_PROFILE=1 timing hooks around kernel call sites
- ``dashboard``: live terminal poller (``python -m repro.obs.dashboard``)
"""
from repro.obs.hub import (  # noqa: F401
    Counter, Gauge, Histogram, MetricsHub, LADDERS,
    get_hub, reset_hub, set_disabled, metrics_disabled,
    render_prometheus, quantile_from_state, merge_hist_states, hist_summary,
)
from repro.obs.trace import (  # noqa: F401
    TraceLog, get_trace_log, reset_trace_log, new_trace_id,
)
from repro.obs.profile import (  # noqa: F401
    profiling_enabled, profile_call, profile_span,
)
from repro.obs.dump import (  # noqa: F401
    MetricsJsonDumper, scrape_payload,
)
