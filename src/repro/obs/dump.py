"""Exposition plumbing shared by the launchers and the net servers.

``scrape_payload`` is the one canonical shape a telemetry consumer sees —
the same dict whether it arrives as a ``metrics`` wire frame (query_serve
``--serve`` / stream_ingest ``--listen``), a ``--metrics-json`` file on
disk, or a ``repro.obs.dashboard`` poll:

    {"prometheus": <text exposition>, "state": <merged hub state>, "ts": ...}

``MetricsJsonDumper`` is the file flavour: a daemon thread renders the
payload every ``interval_s`` and lands it with write-to-tmp + ``os.replace``
so a concurrent reader (the dashboard, a CI assertion) never sees a torn
JSON document.
"""
from __future__ import annotations

import json
import os
import threading
import time

from repro.obs.hub import get_hub, render_prometheus


def scrape_payload() -> dict:
    """One telemetry scrape: the process-global hub, merged across adopted
    workers, as both Prometheus text and the raw state dict."""
    state = get_hub().merged_state()
    return {"prometheus": render_prometheus(state), "state": state,
            "ts": time.time()}


class MetricsJsonDumper:
    """Periodically dump ``scrape_payload()`` to ``path`` atomically."""

    def __init__(self, path: str, interval_s: float = 1.0) -> None:
        self.path = path
        self.interval_s = max(0.05, float(interval_s))
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.writes = 0

    def write_once(self) -> None:
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump(scrape_payload(), f)
        os.replace(tmp, self.path)
        self.writes += 1

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.write_once()
            except OSError:
                pass  # transient fs trouble must not kill the dump cadence

    def start(self) -> "MetricsJsonDumper":
        self.write_once()  # the file exists before the workload starts
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="metrics-json-dumper")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the cadence and land one final dump (the post-drain state —
        the one a scripted run actually wants to read)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self.write_once()
