"""Distributed kMatrix: the paper's technique scaled out (paper §VI lists
"data partitioning across machines" as future work — this implements it).

Two orthogonal distribution modes, composable on a ("data", "model") mesh:

  DATA-PARALLEL (exact, embarrassingly so): counters are additive, so each
  data shard sketches its sub-stream into a local replica and queries psum
  across the axis (or merge periodically).  This is `dp_ingest` +
  `dp_edge_freq` under shard_map.

  PARTITION-PARALLEL (the kMatrix structure IS a routing table): partitions
  are sharded over the "model" axis like MoE experts; each device owns
  ``P / n_model`` partition slabs.  Edges route by source vertex ->
  partition -> owner device.  Two dispatch strategies:

    * "allgather" — every device all-gathers the edge batch and ingests
      only edges owned locally.  EXACT; wire bytes = B * n_model. This is
      the baseline collective schedule.
    * "a2a" — bucket edges per owner with a static capacity and exchange
      via all_to_all; wire bytes = B * capacity_factor.  Overflow beyond
      capacity is counted and returned (a production deployment loops the
      tail; the benchmark asserts zero drops at cf=2).

  EXPERIMENTS.md §Perf compares the two collective schedules' roofline
  terms — a2a moves ~n_model x fewer bytes and wins whenever the stream is
  well spread across partitions (which the banded partitioner guarantees by
  construction: bands are equal-count).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

# jax 0.4.x mis-types the scan inside the searchsorted-based route lookup
# under shard_map ("Scan carry input and output got mismatched replication
# types"), and its own error message prescribes check_rep=False.  Scope the
# workaround to affected versions so newer jax keeps replication checking
# (the guard that catches e.g. a dropped psum) enabled.
_CHECK_REP_COMPAT = (
    {"check_rep": False} if jax.__version__.startswith("0.4.") else {}
)

from repro.common.hashing import fastrange
from repro.core.kmatrix import KMatrix
from repro.core.types import EdgeBatch


# ----------------------------------------------------------- data parallel

def make_dp_ingest(sk_template: KMatrix, mesh, axis: str = "data"):
    """Returns ingest(replicated_pool_stack, batch_shard) under shard_map.

    Pool state is stored SHARDED over the data axis as independent replicas
    (shape [d, pool]); merge happens at query time via psum.
    """

    def local_ingest(pool, conn, src, dst, wt):
        sk = sk_template.replace(pool=pool, conn=conn)
        from repro.core import kmatrix

        new = kmatrix.ingest(sk, EdgeBatch(src=src, dst=dst, weight=wt))
        return new.pool, new.conn

    d, pool_size = sk_template.pool.shape
    return shard_map(
        local_ingest,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None, None), P(axis), P(axis), P(axis)),
        out_specs=(P(axis, None), P(axis, None, None)),
        **_CHECK_REP_COMPAT,
    )


def make_dp_edge_freq(sk_template: KMatrix, mesh, axis: str = "data"):
    """Query across data-parallel replicas: psum partial counters, then min."""

    def local_query(pool, conn, src, dst):
        from repro.core import kmatrix

        pool = jax.lax.psum(pool, axis)
        sk = sk_template.replace(pool=pool, conn=conn)
        est = kmatrix.edge_freq(sk, src, dst)
        return est

    return shard_map(
        local_query,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None, None), P(None), P(None)),
        out_specs=P(None),
        **_CHECK_REP_COMPAT,
    )


# ------------------------------------------------------ partition parallel

def build_owner_map(sk: KMatrix, n_model: int) -> np.ndarray:
    """Assign partitions to model-axis devices, balancing total slab area."""
    widths = np.asarray(sk.route.widths)
    areas = widths.astype(np.int64) ** 2
    order = np.argsort(-areas)  # biggest first, greedy bin pack
    owner = np.zeros(len(widths), np.int32)
    load = np.zeros(n_model, np.int64)
    for p in order:
        dev = int(np.argmin(load))
        owner[p] = dev
        load[dev] += areas[p]
    return owner


def make_pp_ingest(
    sk_template: KMatrix,
    mesh,
    *,
    mode: str = "a2a",
    capacity_factor: float = 2.0,
    data_axis=None,  # str or tuple; default: every non-model axis
    model_axis: str = "model",
):
    """Partition-parallel ingest under shard_map.

    State layout:每 model shard holds the FULL flat pool buffer but only
    writes its owned slabs (memory-lean layouts would slice the pool per
    owner; we keep the flat buffer so estimates stay one gather — the
    unwritten regions are zeros and a psum(model) at query time
    reconstitutes the global pool).

    Returns (ingest_fn, owner_map). ingest_fn(pool, conn, src, dst, wt)
    with pool sharded P(model_axis-replicated...) — see specs below — and
    edges sharded over the data axis; returns updated (pool, conn, dropped).
    """
    if data_axis is None:
        data_axis = tuple(a for a in mesh.axis_names if a != model_axis)
    data_axes = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)
    n_model = mesh.shape[model_axis]
    owner_np = build_owner_map(sk_template, n_model)
    owner_map = jnp.asarray(owner_np)
    d = sk_template.depth

    # State layout: every (data, model) device holds its own (d, pool) and
    # (d, cw, cw) replica rows — stacked over BOTH axes — so the out-specs
    # never claim replication the program doesn't enforce. Queries psum the
    # slab-disjoint pools over both axes. conn writes are gated to model
    # rank 0 (each edge must count once, and every model rank in a data row
    # sees the same edge shard).

    def local(pool, conn, src, dst, wt):
        my_dev = jax.lax.axis_index(model_axis)
        from repro.core import kmatrix

        def conn_update(conn):
            if sk_template.conn_w == 0:
                return conn
            ci = fastrange(sk_template.hashes.mix(src), sk_template.conn_w)
            cj = fastrange(sk_template.hashes.mix(dst), sk_template.conn_w)
            rows = jnp.arange(d, dtype=jnp.int32)[:, None]
            gate = (my_dev == 0).astype(conn.dtype)
            return conn.at[rows, ci, cj].add(wt[None] * gate)

        # Edges arrive replicated along the model axis (in_spec P(data)):
        # each model rank claims its own 1/n_model slice, so every edge is
        # processed by exactly one rank per data row.
        b = src.shape[0]
        b_m = b // n_model
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, my_dev * b_m, b_m)
        src_m, dst_m, wt_m = sl(src), sl(dst), sl(wt)

        if mode == "allgather":
            # classic dispatch: gather every rank's slice, keep owned edges
            src_all = jax.lax.all_gather(src_m, model_axis, tiled=True)
            dst_all = jax.lax.all_gather(dst_m, model_axis, tiled=True)
            wt_all = jax.lax.all_gather(wt_m, model_axis, tiled=True)
            p = sk_template.route.lookup(src_all)
            mine = owner_map[p] == my_dev
            wt_mine = jnp.where(mine, wt_all, 0)
            sk = sk_template.replace(pool=pool, conn=jnp.zeros_like(conn))
            new = kmatrix.ingest(
                sk, EdgeBatch(src=src_all, dst=dst_all, weight=wt_mine)
            )
            dropped = jnp.zeros((), jnp.int32)
            return new.pool, conn_update(conn), dropped

        # ---- a2a: bucket my slice by owner, exchange, ingest local -------
        cap = int(b_m * capacity_factor / n_model)
        cap = max(cap, 8)
        p = sk_template.route.lookup(src_m)
        own = jnp.where(wt_m > 0, owner_map[p], n_model)  # park padding
        order = jnp.argsort(own)
        own_s = own[order]
        pos = jnp.arange(b_m, dtype=jnp.int32)
        is_start = jnp.concatenate([jnp.ones(1, bool), own_s[1:] != own_s[:-1]])
        start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_start, pos, 0)
        )
        rank_s = pos - start
        rank = jnp.zeros_like(rank_s).at[order].set(rank_s)
        keep = (rank < cap) & (own < n_model)
        slot = jnp.where(keep, rank, cap)
        buck = lambda x, fill: jnp.full((n_model, cap), fill, x.dtype).at[
            jnp.minimum(own, n_model - 1), slot
        ].set(jnp.where(keep, x, fill), mode="drop")
        src_b = buck(src_m, 0)
        dst_b = buck(dst_m, 0)
        wt_b = jnp.full((n_model, cap), 0, wt_m.dtype).at[
            jnp.minimum(own, n_model - 1), slot
        ].set(jnp.where(keep, wt_m, 0), mode="drop")
        # exchange: device m receives bucket m from every model peer
        src_r = jax.lax.all_to_all(src_b, model_axis, 0, 0, tiled=True)
        dst_r = jax.lax.all_to_all(dst_b, model_axis, 0, 0, tiled=True)
        wt_r = jax.lax.all_to_all(wt_b, model_axis, 0, 0, tiled=True)
        sk = sk_template.replace(pool=pool, conn=jnp.zeros_like(conn))
        new = kmatrix.ingest(
            sk,
            EdgeBatch(src=src_r.reshape(-1), dst=dst_r.reshape(-1),
                      weight=wt_r.reshape(-1)),
        )
        dropped = jnp.sum((~keep & (own < n_model)).astype(jnp.int32))
        dropped = jax.lax.psum(dropped, model_axis)
        for ax in data_axes:
            dropped = jax.lax.psum(dropped, ax)
        dropped = dropped // n_model  # model ranks of a row count same drops
        return new.pool, conn_update(conn), dropped

    both = data_axes + (model_axis,)
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(both, None),  # pool: per-device replica rows (stacked)
            P(both, None, None),  # conn: per-device rows, model-0-gated
            P(data_axes),
            P(data_axes),
            P(data_axes),
        ),
        out_specs=(P(both, None), P(both, None, None), P()),
        **_CHECK_REP_COMPAT,
    )
    return fn, owner_np


def make_pp_edge_freq(sk_template: KMatrix, mesh, *,
                      data_axis=None, model_axis: str = "model"):
    """Query on partition-parallel state: psum the slab-disjoint pools over
    both axes (model shards are slab-disjoint, data shards are additive)."""
    if data_axis is None:
        data_axis = tuple(a for a in mesh.axis_names if a != model_axis)
    data_axes = (data_axis,) if isinstance(data_axis, str) else tuple(data_axis)

    def local(pool, conn, src, dst):
        from repro.core import kmatrix

        pool = jax.lax.psum(pool, model_axis)
        conn = jax.lax.psum(conn, model_axis)
        for ax in data_axes:
            pool = jax.lax.psum(pool, ax)
            conn = jax.lax.psum(conn, ax)
        sk = sk_template.replace(pool=pool, conn=conn)
        return kmatrix.edge_freq(sk, src, dst)

    both = data_axes + (model_axis,)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(both, None), P(both, None, None), P(None), P(None)),
        out_specs=P(None),
        **_CHECK_REP_COMPAT,
    )
