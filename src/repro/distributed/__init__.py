from repro.distributed.sketch_parallel import (
    build_owner_map,
    make_dp_edge_freq,
    make_dp_ingest,
    make_pp_edge_freq,
    make_pp_ingest,
)

__all__ = [
    "build_owner_map",
    "make_dp_edge_freq",
    "make_dp_ingest",
    "make_pp_edge_freq",
    "make_pp_ingest",
]
