"""Tour of the Type II query surface on kMatrix (what CountMin can't do).

    PYTHONPATH=src python examples/sketch_queries.py

Builds a small social-network-like stream and answers: edge frequency,
node in/out aggregates, reachability (vs networkx ground truth), heavy
nodes via the vectorized reverse sweep, and path weights.
"""
import networkx as nx
import numpy as np
import jax.numpy as jnp

from repro.core import EdgeBatch, KMatrix, kmatrix, queries, vertex_stats_from_sample
from repro.core.metrics import exact_edge_frequencies, lookup_exact


def main() -> None:
    rng = np.random.default_rng(7)
    n_nodes = 400
    # hub structure: node 7 posts a lot; a few chains for reachability
    src = np.concatenate([
        np.full(600, 7, np.int32),
        rng.integers(0, n_nodes, 2400).astype(np.int32),
        np.asarray([100, 101, 102, 103], np.int32),
    ])
    dst = np.concatenate([
        rng.integers(0, n_nodes, 600).astype(np.int32),
        rng.integers(0, n_nodes, 2400).astype(np.int32),
        np.asarray([101, 102, 103, 104], np.int32),
    ])
    keep = src != dst
    src, dst = src[keep], dst[keep]

    stats = vertex_stats_from_sample(src[:1500], dst[:1500])
    sk = KMatrix.create(bytes_budget=128 * 1024, stats=stats, depth=5, seed=0,
                        conn_frac=0.3)
    sk = kmatrix.ingest(sk, EdgeBatch.from_numpy(src, dst))

    # --- edge frequency --------------------------------------------------
    fmap = exact_edge_frequencies(src, dst, np.ones_like(src))
    qs, qd = src[:8], dst[:8]
    est = np.asarray(kmatrix.edge_freq(sk, jnp.asarray(qs), jnp.asarray(qd)))
    true = lookup_exact(fmap, qs, qd)
    print("edge freq (est vs true):",
          list(zip(est.tolist(), true.astype(int).tolist())))

    # --- node aggregates --------------------------------------------------
    out7 = int(kmatrix.node_out_freq(sk, jnp.asarray([7], jnp.int32))[0])
    out_typical = int(kmatrix.node_out_freq(sk, jnp.asarray([42], jnp.int32))[0])
    print(f"node 7 out-aggregate ~{out7} (true {int((src == 7).sum())}); "
          f"node 42 ~{out_typical} (true {int((src == 42).sum())})")

    # --- heavy nodes: reverse sweep over the universe ---------------------
    ids, freqs = queries.heavy_nodes(
        lambda v: kmatrix.node_out_freq(sk, v), n_nodes, threshold=300,
        chunk=128)
    ids = np.asarray(ids)
    print("heavy nodes (threshold 300):", sorted(set(ids[ids >= 0].tolist())))

    # --- reachability vs networkx ----------------------------------------
    g = nx.DiGraph(zip(src.tolist(), dst.tolist()))
    pairs = [(100, 104), (104, 100), (100, 103)]
    est_reach = np.asarray(queries.kmatrix_reachability(
        sk, jnp.asarray([p[0] for p in pairs], jnp.int32),
        jnp.asarray([p[1] for p in pairs], jnp.int32)))
    for (a, b), e in zip(pairs, est_reach):
        t = nx.has_path(g, a, b)
        print(f"reach {a}->{b}: sketch={bool(e)} true={t}"
              f"{'  (false positive)' if e and not t else ''}")

    # --- path weight -------------------------------------------------------
    pw = float(queries.path_weight(
        lambda s, d: kmatrix.edge_freq(sk, s, d),
        jnp.asarray([100, 101, 102, 103, 104], jnp.int32)))
    print(f"path 100->...->104 weight >= {pw:.0f} (true 4)")


if __name__ == "__main__":
    main()
