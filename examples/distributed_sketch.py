"""Distributed kMatrix on a (data x model) mesh — the paper's §VI future
work ("data partitioning across machines") implemented.

    PYTHONPATH=src python examples/distributed_sketch.py

Forces 8 host devices, builds a (2 data x 4 model) mesh, and runs
  1. data-parallel ingest (counter additivity; psum at query), and
  2. partition-parallel ingest (partitions sharded like MoE experts;
     edges routed by source vertex; all_to_all vs all_gather dispatch),
verifying both against a single-device reference.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import KMatrix, kmatrix, vertex_stats_from_sample
from repro.core.metrics import exact_edge_frequencies, lookup_exact
from repro.distributed.sketch_parallel import (
    make_dp_edge_freq,
    make_dp_ingest,
    make_pp_edge_freq,
    make_pp_ingest,
)
from repro.streams import make_stream, sample_stream


def main() -> None:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    print(f"devices: {len(jax.devices())}, mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    stream = make_stream("cit-HepPh", batch_size=2048, seed=3, scale=0.05)
    ssrc, sdst, sw = sample_stream(stream, 4000, seed=5)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)
    sk0 = KMatrix.create(bytes_budget=1 << 16, stats=stats, depth=3, seed=1)
    print(f"kmatrix: {sk0.route.n_partitions} partitions, "
          f"pool {sk0.pool_size} cells/layer")

    # single-device reference
    ref = sk0
    ing = jax.jit(kmatrix.ingest)
    for b in stream:
        ref = ing(ref, b)
    qs, qd, _ = sample_stream(stream, 256, seed=9)
    ref_est = np.asarray(kmatrix.edge_freq(ref, jnp.asarray(qs), jnp.asarray(qd)))

    # 1. data-parallel
    with jax.set_mesh(mesh):
        dp_ingest = make_dp_ingest(sk0, mesh)
        dp_query = make_dp_edge_freq(sk0, mesh)
        n_data = mesh.shape["data"]
        pool = jnp.zeros((n_data * sk0.pool.shape[0], sk0.pool.shape[1]), jnp.int32)
        conn = jnp.zeros((n_data * sk0.conn.shape[0],) + sk0.conn.shape[1:], jnp.int32)
        for b in stream:
            pool, conn = dp_ingest(pool, conn, b.src, b.dst, b.weight)
        dp_est = np.asarray(dp_query(pool, conn, jnp.asarray(qs), jnp.asarray(qd)))
    print(f"data-parallel exact match:      {(dp_est == ref_est).all()}")

    # 2. partition-parallel (both dispatch modes)
    for mode in ["allgather", "a2a"]:
        with jax.set_mesh(mesh):
            pp_ingest, owner = make_pp_ingest(sk0, mesh, mode=mode,
                                              capacity_factor=2.0)
            pp_query = make_pp_edge_freq(sk0, mesh)
            n_rep = mesh.shape["data"] * mesh.shape["model"]
            pool = jnp.zeros((n_rep * sk0.pool.shape[0], sk0.pool.shape[1]),
                             jnp.int32)
            conn = jnp.zeros((n_rep * sk0.conn.shape[0],) + sk0.conn.shape[1:],
                             jnp.int32)
            dropped = 0
            for b in stream:
                pool, conn, d = pp_ingest(pool, conn, b.src, b.dst, b.weight)
                dropped += int(d)
            est = np.asarray(pp_query(pool, conn, jnp.asarray(qs), jnp.asarray(qd)))
        tag = "exact match" if (est == ref_est).all() else \
            f"max undercount {int((ref_est - est).max())} (cap overflow)"
        print(f"partition-parallel [{mode:9s}]: {tag}; "
              f"owner loads {np.bincount(owner, minlength=4).tolist()}, "
              f"dropped={dropped}")


if __name__ == "__main__":
    main()
