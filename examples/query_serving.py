"""Serving tour: snapshot-isolated queries over a live-ingesting kMatrix.

    PYTHONPATH=src python examples/query_serving.py

Opens two tenants in a sketch registry (same dataset, different budgets),
interleaves ingest with a mixed query batch through the batched engine, and
demonstrates the three serving guarantees:

  1. snapshot isolation — a held snapshot answers identically even after
     more stream batches are ingested and published;
  2. exactness — engine answers == direct repro.core.queries answers;
  3. closure caching — repeated reachability on one epoch hits the cached
     boolean-closure matrices instead of re-running the matmul cascade.
"""
import numpy as np

from repro.serving import (
    QueryEngine,
    SketchRegistry,
    WorkloadMix,
    synth_requests,
)
from repro.serving import engine as eng


def main() -> None:
    registry = SketchRegistry(depth=5, scale=0.1)
    small = registry.open("cit-HepPh", "kmatrix", 128, seed=0)
    large = registry.open("cit-HepPh", "kmatrix", 512, seed=0)
    print(f"registry: {len(registry)} tenants")

    # ingest a prefix of the stream and publish epoch 1 on both tenants
    registry.step_all(3)
    registry.publish_all()

    engine = QueryEngine()
    n_nodes = small.stream.spec.n_nodes
    requests = [
        eng.edge_freq(1, 2),
        eng.node_out(7),
        eng.reach(3, 40),
        eng.path_weight([1, 2, 3, 4]),
        eng.subgraph_weight([(1, 2), (2, 3)]),
        eng.heavy_nodes(n_nodes, threshold=200.0),
    ]

    for tenant in (small, large):
        res = engine.execute(tenant.snapshot, requests)
        printable = [
            (r.family, r.value if r.family != "heavy_nodes"
             else f"{len(r.value[0])} heavy ids") for r in res]
        print(f"{tenant.key.tenant_id} epoch {tenant.epoch}: {printable}")

    # --- 1. snapshot isolation -------------------------------------------
    held = small.snapshot
    before = [r.value for r in engine.execute(held, requests[:3])]
    small.step(2)           # keep ingesting...
    small.publish()         # ...and publish a NEW epoch
    after_held = [r.value for r in engine.execute(held, requests[:3])]
    after_new = [r.value for r in engine.execute(small.snapshot, requests[:3])]
    assert before == after_held, "held snapshot must not move"
    print(f"isolation: held epoch {held.epoch} answers stable "
          f"{before} vs new epoch {small.epoch} answers {after_new}")

    # --- 2. exactness vs direct module-level queries ----------------------
    direct = eng.direct_answers(small.snapshot, requests[:5])
    batched = [r.value for r in engine.execute(small.snapshot, requests[:5])]
    assert batched == direct, (batched, direct)
    print(f"exactness: engine == direct for {len(direct)} mixed queries")

    # --- 3. closure cache across a mixed workload ------------------------
    mix = WorkloadMix(edge_freq=0.3, reach=0.7, node_out=0.0,
                      path_weight=0.0, subgraph_weight=0.0, heavy_nodes=0.0)
    workload = synth_requests(400, mix, n_nodes=n_nodes, seed=3)
    engine.execute(small.snapshot, workload)
    engine.execute(small.snapshot, workload)
    s = engine.stats
    print(f"closure cache: {s['closure_hits']} hits / "
          f"{s['closure_misses']} misses across "
          f"{s['batches_planned']} planned batches")


if __name__ == "__main__":
    main()
