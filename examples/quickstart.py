"""Quickstart: summarize a graph stream with kMatrix in ~60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's pipeline end to end: reservoir sample -> error-optimal
partition plan -> batched ingest -> frequency / reachability queries, and
compares kMatrix against TCM/gMatrix at the same memory budget.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    KMatrix,
    MatrixSketch,
    kmatrix,
    matrix_sketch,
    queries,
    vertex_stats_from_sample,
)
from repro.core.metrics import (
    average_relative_error,
    exact_edge_frequencies,
    lookup_exact,
    percent_effective_queries,
)
from repro.streams import make_stream, sample_stream


def main() -> None:
    budget_kb, depth = 256, 5
    stream = make_stream("cit-HepPh", batch_size=8192, seed=1, scale=0.25)
    print(f"stream: {stream.spec.n_edges} edges over "
          f"{stream.spec.n_nodes} nodes ({stream.num_batches} batches)")

    # 1. Reservoir-sample the stream and plan the partitions (paper §IV-A).
    ssrc, sdst, sw = sample_stream(stream, 10_000, seed=7)
    stats = vertex_stats_from_sample(ssrc, sdst, sw)

    sketches = {
        "tcm": (MatrixSketch.create(bytes_budget=budget_kb * 1024, depth=depth,
                                    seed=3, kind="tcm"), matrix_sketch),
        "gmatrix": (MatrixSketch.create(bytes_budget=budget_kb * 1024,
                                        depth=depth, seed=4, kind="gmatrix"),
                    matrix_sketch),
        "kmatrix": (KMatrix.create(bytes_budget=budget_kb * 1024, stats=stats,
                                   depth=depth, seed=3), kmatrix),
    }
    km = sketches["kmatrix"][0]
    print(f"kmatrix: {km.route.n_partitions} partitions, widths "
          f"{np.asarray(km.route.widths).tolist()}")

    # 2. Stream ingest (batched, jit).
    states = {}
    for name, (sk, mod) in sketches.items():
        ing = jax.jit(mod.ingest)
        t0 = time.time()
        for batch in stream:
            sk = ing(sk, batch)
        jax.block_until_ready(sk.pool if hasattr(sk, "pool") else sk.table)
        states[name] = sk
        rate = stream.spec.n_edges / (time.time() - t0) / 1e6
        print(f"  {name:8s} ingest {rate:5.1f} M edges/s")

    # 3. Query accuracy vs exact ground truth (paper Fig. 7/8 protocol).
    src, dst, w = stream.all_edges_numpy()
    fmap = exact_edge_frequencies(src, dst, w)
    qs, qd, _ = sample_stream(stream, 5_000, seed=99)
    true = jnp.asarray(lookup_exact(fmap, qs, qd))
    print(f"\n{'sketch':10s} {'ARE':>8s} {'PEQ@10':>8s}")
    for name, sk in states.items():
        mod = sketches[name][1]
        est = mod.edge_freq(sk, jnp.asarray(qs), jnp.asarray(qd))
        are = float(average_relative_error(est, true))
        peq = float(percent_effective_queries(est, true, 10.0))
        print(f"{name:10s} {are:8.2f} {peq:7.1f}%")

    # 4. Type II queries on kMatrix (what CountMin/gSketch cannot answer).
    sk = states["kmatrix"]
    qs5, qd5 = jnp.asarray(qs[:5]), jnp.asarray(qd[:5])
    reach = queries.kmatrix_reachability(sk, qs5, qd5, max_hops=8)
    out_f = kmatrix.node_out_freq(sk, qs5)
    print("\nreachability(sample pairs):", np.asarray(reach).tolist())
    print("node out-frequency:        ", np.asarray(out_f).tolist())


if __name__ == "__main__":
    main()
