"""Background ingest runtime tour: workers, backpressure, crash recovery.

    PYTHONPATH=src python examples/background_ingest.py

Walks the `repro.runtime` layer end to end:

  1. concurrency — two tenants ingest their streams in background worker
     threads while the main thread fires queries the whole time; epochs
     advance under live query load, answers stay snapshot-consistent;
  2. lifecycle + metrics — live queue depth / edges-per-s / publish latency
     while running, then a graceful drain-and-stop whose conservation
     report accounts every offered edge (published + drops, zero silent);
  3. crash safety — a second run is killed mid-stream, restored from its
     last checkpoint into a fresh registry, resumed, and ends bit-identical
     to the never-crashed sketch (seekable streams + additive counters).
"""
import tempfile
import time

import numpy as np

from repro.runtime import Runtime
from repro.serving import QueryEngine, SketchRegistry
from repro.serving import engine as eng


def wait_until(cond, timeout_s=60.0, poll_s=0.01):
    deadline = time.monotonic() + timeout_s
    while not cond():
        assert time.monotonic() < deadline, "timed out"
        time.sleep(poll_s)


def main() -> None:
    # ---- 1 + 2: two tenants ingesting in the background under query load --
    registry = SketchRegistry(depth=3, batch_size=2048, scale=0.05)
    t_small = registry.open("cit-HepPh", "kmatrix", 128, seed=0)
    t_large = registry.open("cit-HepPh", "kmatrix", 512, seed=0)

    runtime = Runtime(queue_capacity=8, backpressure="block",
                      publish_policy="every:2", reservoir_k=1024)
    for tenant in (t_small, t_large):
        runtime.attach(tenant, throttle_s=0.02)  # throttle: keep it watchable

    engine = QueryEngine(min_bucket=8)
    queries = [eng.edge_freq(1, 2), eng.node_out(7), eng.reach(3, 11)]
    engine.execute(t_small.snapshot, queries)  # compile before the clock

    runtime.start()
    epochs_seen: list[int] = []
    while not runtime.join_pumps(timeout=0.05):
        res = engine.execute(t_small.snapshot, queries)
        assert len({r.epoch for r in res}) == 1, "one batch, one epoch"
        epochs_seen.append(res[0].epoch)
    m = runtime.metrics()[t_small.key.tenant_id]
    print(f"live metrics: depth={m['queue_depth']} "
          f"edges/s={m['edges_per_s_ewma']} epoch={m['epoch']} "
          f"publish_ms={m['last_publish_latency_ms']}")
    # HOW MANY distinct epochs the loop catches is scheduling-dependent;
    # what is guaranteed is that the ones it sees never regress
    assert epochs_seen == sorted(epochs_seen), "epochs regressed"
    print(f"queried across {len(set(epochs_seen))} live epoch(s): "
          f"{sorted(set(epochs_seen))}")

    report = runtime.stop(drain=True)
    assert t_small.epoch > 0, "background ingest must have published"
    for tid, r in report.items():
        print(f"{tid}: offered={r['offered_edges']} "
              f"published={r['published_edges']} dropped={r['dropped_edges']} "
              f"unaccounted={r['unaccounted_edges']}")
        assert r["unaccounted_edges"] == 0, "graceful drain lost edges"
    sample = runtime.handles()[0].worker.reservoir.sample
    print(f"online reservoir sample: {len(sample[0])} edges maintained")

    # ---- 3: kill mid-stream, restore from checkpoint, resume --------------
    ckpt_dir = tempfile.mkdtemp(prefix="runtime_ckpt_")
    reg_a = SketchRegistry(depth=3, batch_size=2048, scale=0.05)
    victim = reg_a.open("cit-HepPh", "kmatrix", 128, seed=7)
    rt_a = Runtime(queue_capacity=2, publish_policy="every:2",
                   checkpoint_dir=ckpt_dir, checkpoint_every=1, poll_s=0.01)
    handle = rt_a.attach(victim, throttle_s=0.05)
    rt_a.start()
    wait_until(lambda: handle.worker.metrics.checkpoints >= 2)
    rt_a.kill()  # crash-like: queued + in-delta work is abandoned
    print(f"killed mid-stream at offset {victim.offset} "
          f"({handle.worker.metrics.checkpoints} checkpoints on disk)")

    reg_b = SketchRegistry(depth=3, batch_size=2048, scale=0.05)
    resumed = reg_b.open("cit-HepPh", "kmatrix", 128, seed=7)
    rt_b = Runtime(queue_capacity=8, publish_policy="every:2",
                   checkpoint_dir=ckpt_dir)
    rt_b.attach(resumed, restore=True)
    print(f"restored: epoch={resumed.epoch} offset={resumed.offset}")
    rt_b.start()
    assert rt_b.join_pumps(120)
    rt_b.stop(drain=True)

    # oracle: the same stream ingested once, no crash
    import jax
    from repro.core import kmatrix
    reg_c = SketchRegistry(depth=3, batch_size=2048, scale=0.05)
    oracle = reg_c.open("cit-HepPh", "kmatrix", 128, seed=7)
    sk = oracle.snapshot.sketch
    ing = jax.jit(kmatrix.ingest)
    for b in oracle.stream:
        sk = ing(sk, b)
    assert (np.asarray(resumed.snapshot.sketch.pool)
            == np.asarray(sk.pool)).all()
    assert (np.asarray(resumed.snapshot.sketch.conn)
            == np.asarray(sk.conn)).all()
    print("crash -> restore -> resume is bit-identical to a clean run ✓")


if __name__ == "__main__":
    main()
    # Skip interpreter teardown: XLA's CPU client occasionally aborts
    # ("terminate called without an active exception") while destroying its
    # thread pools after a multi-threaded run.  All runtimes are stopped and
    # all assertions have passed by this point; there is nothing to clean up.
    import os
    import sys

    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(0)
