"""End-to-end training driver: a ~100M-param gemma-2-style LM for a few
hundred steps on synthetic Zipf token streams, with checkpointing and
crash-safe resume — runnable on this CPU container.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

(--tiny switches to a ~1M-param config so CI finishes in seconds.)
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.lm import GEMMA2_2B, reduced
from repro.launch.train import synthetic_lm_batch
from repro.training import AdamWConfig, init_train_state, make_train_step
from repro.training.steps import lm_loss_fn


def config_100m():
    """gemma-2 topology at ~100M params (24 + 77 embed)."""
    return dataclasses.replace(
        GEMMA2_2B,
        name="gemma2-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_head=64,
        d_ff=2048,
        vocab=32_000,
        window=256,
        attn_chunk_q=128,
        attn_chunk_kv=256,
        ce_chunk=128,
        dtype="float32",
        remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()

    cfg = reduced(GEMMA2_2B) if args.tiny else config_100m()
    print(f"config {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params")

    opt = AdamWConfig(lr_peak=3e-3, warmup_steps=args.steps // 10,
                      total_steps=args.steps)
    params = jax.jit(
        lambda k: __import__("repro.models.transformer.model",
                             fromlist=["init_params"]).init_params(cfg, k)
    )(jax.random.PRNGKey(0))
    state = init_train_state(params, opt)
    step_fn = jax.jit(make_train_step(lm_loss_fn(cfg), opt))

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        rng = np.random.default_rng(step)
        batch = synthetic_lm_batch(rng, args.batch, args.seq, cfg.vocab)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 25 == 0:
            print(f"step {step+1:4d}  loss {np.mean(losses[-25:]):.4f}  "
                  f"({args.batch*args.seq*25/(time.time()-t0):,.0f} tok/s)")
            t0 = time.time()
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.2 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
